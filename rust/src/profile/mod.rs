//! Kernel-level profiling + model-drift observability (ISSUE 10
//! tentpole): count the data movement the real hot paths *observably*
//! perform, diff it against what [`crate::traffic`] *predicted* for
//! the same prepared plan, and feed the measured gap back into the
//! tuner as a calibration.
//!
//! The paper's whole argument is a data-movement argument — explicit
//! shm caching of x and compact u16 columns cut bytes moved — and
//! since PR 7 the traffic simulator *drives* tuning and reorder
//! decisions. An autotuner is only as good as its cost model
//! (Akbudak–Kayaaslan–Aykanat's OSKI analysis, PAPERS.md), so this
//! layer closes the loop:
//!
//! 1. **Observe** — engines carry a [`ProfileState`] and record, per
//!    `spmv`/`spmv_batch` call, the bytes their walk moves: ELL-walk
//!    stream (slice values + u16 cols), explicit x-cache fills, ER-tail
//!    stream and `y_idx_er` scatter width, x-gather footprint (distinct
//!    cache lines via a coarse bitmap), SpMM register-block reuse,
//!    pad-slot waste, per-shard halo bytes. All counters are
//!    *structural* — they depend only on the matrix and plan, never on
//!    x values — so the per-engine cost is computed once
//!    ([`CallCost`]) and each call is a handful of relaxed atomic adds
//!    plus one clock read. The aggregate is a [`KernelProfile`].
//! 2. **Diff** — [`DriftReport`] replays the same plan through
//!    [`crate::traffic`] and compares predicted vs observed bytes and
//!    secs per component (ELL vs ER vs halo vs x-fetch), so a drifting
//!    prediction names its cause.
//! 3. **Calibrate** — [`Calibration`] least-squares-fits per-level
//!    secs/byte scales from measured samples and rescales
//!    [`crate::traffic::TrafficReport::predicted_secs`] so the
//!    Heuristic oracle tracks the host it actually runs on; it
//!    persists via the plan store's atomic JSON.
//!
//! Everything is behind the on-by-default `profile` cargo feature with
//! the same twin discipline PR 9 used for `simd`: both legs always
//! compile; with the feature off every recording method early-returns
//! before touching a counter and [`timer`] returns `None`, so the
//! kernels are bitwise identical either way (gated by
//! `tests/profile.rs`).

use crate::gpu::device::GpuDevice;
use crate::runtime::json::{obj, Json};
use crate::sparse::csr::Csr;
use crate::sparse::ehyb::EhybMatrix;
use crate::sparse::scalar::Scalar;
use crate::traffic::{spmm_register_blocks, TrafficReport};
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::OnceLock;
use std::time::Instant;

/// Default relative drift past which a prediction is considered to
/// have diverged from observation (15%, the acceptance bound the CI
/// smoke gate enforces on `drift-*` rows).
pub const DEFAULT_DRIFT_THRESHOLD: f64 = 0.15;

/// True when the crate was built with the `profile` feature; recording
/// is a no-op otherwise.
#[inline(always)]
pub fn enabled() -> bool {
    cfg!(feature = "profile")
}

/// Start a per-call timer — `None` (and thus zero cost) when the
/// `profile` feature is off, so the off-leg never reads the clock.
#[inline(always)]
pub fn timer() -> Option<Instant> {
    enabled().then(Instant::now)
}

/// Seconds elapsed since [`timer`], 0.0 on the off-leg.
#[inline(always)]
pub fn elapsed(t: Option<Instant>) -> f64 {
    t.map_or(0.0, |t| t.elapsed().as_secs_f64())
}

/// Bytes one kernel invocation moves, split the same way
/// [`crate::traffic::ComponentBytes`] attributes the simulated replay.
/// Everything here is structural — computed once per engine from the
/// prepared matrix, then multiplied per call by the register-block /
/// lane counts — which is what makes recording cheap enough to leave
/// on by default.
///
/// "Per block" fields are charged once per SpMM register block
/// ([`spmm_register_blocks`]; a single `spmv` is one block of one
/// lane); "per lane" fields are charged once per right-hand side.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CallCost {
    /// Primary format stream, per block: ELL slice values + u16 cols
    /// for EHYB, the whole cols+vals stream for CSR walks.
    pub ell_stream: u64,
    /// Descriptor bytes read with the primary stream, per block
    /// (slice ptr/width pairs, CSR row pointers).
    pub meta_block: u64,
    /// ER-tail stream (u32 cols + values), per lane.
    pub er_stream: u64,
    /// ER descriptors + `y_idx_er` reads, per lane.
    pub meta_lane: u64,
    /// Explicit shared-memory x-cache fills, per lane.
    pub x_fill: u64,
    /// Uncached x gather lanes (ER tail / CSR gathers), logical bytes
    /// per lane.
    pub x_gather: u64,
    /// Output-vector writes, per lane.
    pub write: u64,
    /// Distinct 64-byte x cache lines the uncached gathers touch
    /// (coarse-bitmap footprint; the compulsory gather working set).
    pub x_lines: u64,
    /// Stored slots minus logical nonzeros (ELL + ER padding).
    pub pad_slots: u64,
    /// Stream bytes those pad slots waste in a single-lane walk.
    pub pad_bytes: u64,
    /// Rows the ER tail scatters into (`y_idx_er` width).
    pub er_scatter_rows: u64,
    /// Useful flops per lane (2·nnz).
    pub flops: u64,
}

/// Count distinct 64-byte lines among `x[c]` touches (tau-byte
/// elements, indices `< n`) with a flat bitmap — O(nnz) once per
/// engine, never per call.
fn distinct_x_lines(cols: impl Iterator<Item = usize>, n: usize, tau: u64) -> u64 {
    const LINE: u64 = 64;
    let nlines = (n as u64 * tau).div_ceil(LINE) as usize + 1;
    let mut bm = vec![0u64; nlines.div_ceil(64)];
    let mut count = 0u64;
    for c in cols {
        let l = (c as u64 * tau / LINE) as usize;
        let (w, b) = (l / 64, l % 64);
        if bm[w] & (1 << b) == 0 {
            bm[w] |= 1 << b;
            count += 1;
        }
    }
    count
}

impl CallCost {
    /// Closed-form cost of one EHYB walk — exactly the byte streams
    /// [`crate::traffic::ehyb_traffic`] replays (`tests/profile.rs`
    /// pins the equality component by component).
    pub fn of_ehyb<S: Scalar>(e: &EhybMatrix<S>) -> CallCost {
        let tau = S::BYTES as u64;
        let h = e.slice_height as u64;
        let ell_slots = e.ell_vals.len() as u64;
        let er_slots = e.er_vals.len() as u64;
        let er_slices = e.er_slice_width.len() as u64;
        let padded = e.padded_rows() as u64;
        let pad_slots =
            (ell_slots - e.ell_nnz as u64) + (er_slots - e.er_nnz as u64);
        CallCost {
            ell_stream: ell_slots * (2 + tau),
            meta_block: 8 * e.num_slices() as u64,
            er_stream: er_slots * (4 + tau),
            meta_lane: er_slices * (8 + 4 * h),
            x_fill: padded * tau,
            x_gather: er_slots * tau,
            write: padded * tau + er_slices * h * tau,
            // Only the ER tail gathers x uncached; the ELL part reads
            // x through the explicit cache. Padding lanes gather too
            // (they store column 0), exactly like the replay.
            x_lines: distinct_x_lines(
                e.er_cols.iter().map(|&c| c as usize),
                e.padded_rows().max(e.n),
                tau,
            ),
            pad_slots,
            pad_bytes: (ell_slots - e.ell_nnz as u64) * (2 + tau)
                + (er_slots - e.er_nnz as u64) * (4 + tau),
            er_scatter_rows: e.er_rows as u64,
            flops: 2 * e.nnz() as u64,
        }
    }

    /// Closed-form cost of one CSR warp-per-row walk — the stream
    /// shape [`crate::traffic::baseline_traffic`] replays for the
    /// CSR-family engines.
    pub fn of_csr<S: Scalar>(m: &Csr<S>) -> CallCost {
        let tau = S::BYTES as u64;
        let nnz = m.nnz() as u64;
        let nrows = m.nrows() as u64;
        CallCost {
            ell_stream: nnz * (4 + tau),
            meta_block: 8 * nrows,
            x_gather: nnz * tau,
            write: nrows * tau,
            x_lines: distinct_x_lines(
                (0..m.nrows()).flat_map(|r| m.row(r).0.iter().map(|&c| c as usize)),
                m.ncols(),
                tau,
            ),
            flops: 2 * nnz,
            ..CallCost::default()
        }
    }

    /// Closed-form cost of one halo-CSR accumulate pass
    /// ([`EhybShard`](crate::shard::EhybShard)'s cross-shard tail).
    /// Shaped like [`Self::of_csr`] minus the output write: the halo
    /// accumulates into rows the diagonal block already produced, and
    /// [`crate::traffic::shard_traffic`] charges each row's write once
    /// in the block stream, not per tail. The gather bytes here are the
    /// ones the shard snapshot reattributes to `halo_bytes`.
    pub fn of_halo<S: Scalar>(halo: &Csr<S>) -> CallCost {
        let tau = S::BYTES as u64;
        let nnz = halo.nnz() as u64;
        CallCost {
            ell_stream: nnz * (4 + tau),
            meta_block: 8 * halo.nrows() as u64,
            x_gather: nnz * tau,
            x_lines: distinct_x_lines(
                (0..halo.nrows()).flat_map(|r| halo.row(r).0.iter().map(|&c| c as usize)),
                halo.ncols(),
                tau,
            ),
            flops: 2 * nnz,
            ..CallCost::default()
        }
    }

    /// Total bytes of a single-lane walk (one block, one lane).
    pub fn lane_bytes(&self) -> u64 {
        self.ell_stream + self.meta_block + self.er_stream + self.meta_lane
            + self.x_fill
            + self.x_gather
            + self.write
    }
}

/// Per-engine recording state: one lazily computed [`CallCost`] plus
/// relaxed atomic accumulators, so profiling adds no locking to the
/// parallel hot paths. With the `profile` feature off, [`record`]
/// returns before touching anything.
///
/// [`record`]: ProfileState::record
#[derive(Debug, Default)]
pub struct ProfileState {
    cost: OnceLock<CallCost>,
    calls: AtomicU64,
    lanes: AtomicU64,
    blocks: AtomicU64,
    ell_bytes: AtomicU64,
    er_bytes: AtomicU64,
    meta_bytes: AtomicU64,
    x_fill_bytes: AtomicU64,
    x_gather_bytes: AtomicU64,
    write_bytes: AtomicU64,
    nanos: AtomicU64,
}

impl ProfileState {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one kernel invocation over `width` right-hand sides that
    /// took `secs`. `cost` is evaluated once on the first profiled
    /// call; per-block fields are multiplied by the register-block
    /// count of `width`, per-lane fields by `width` — the same charge
    /// [`crate::traffic::ehyb_batch_traffic`] makes, so observed and
    /// simulated totals tie out exactly on the compulsory streams.
    #[inline]
    pub fn record(&self, width: usize, secs: f64, cost: impl FnOnce() -> CallCost) {
        if !enabled() || width == 0 {
            return;
        }
        let c = self.cost.get_or_init(cost);
        let lanes = width as u64;
        let nblocks = spmm_register_blocks(width).len() as u64;
        self.calls.fetch_add(1, Relaxed);
        self.lanes.fetch_add(lanes, Relaxed);
        self.blocks.fetch_add(nblocks, Relaxed);
        self.ell_bytes.fetch_add(c.ell_stream * nblocks, Relaxed);
        self.er_bytes.fetch_add(c.er_stream * lanes, Relaxed);
        self.meta_bytes.fetch_add(c.meta_block * nblocks + c.meta_lane * lanes, Relaxed);
        self.x_fill_bytes.fetch_add(c.x_fill * lanes, Relaxed);
        self.x_gather_bytes.fetch_add(c.x_gather * lanes, Relaxed);
        self.write_bytes.fetch_add(c.write * lanes, Relaxed);
        self.nanos.fetch_add((secs * 1e9) as u64, Relaxed);
    }

    /// Aggregate counters since construction, or `None` when nothing
    /// was recorded (feature off, or no calls yet).
    pub fn snapshot(&self, engine: &str) -> Option<KernelProfile> {
        let calls = self.calls.load(Relaxed);
        if calls == 0 {
            return None;
        }
        let c = self.cost.get().copied().unwrap_or_default();
        let lanes = self.lanes.load(Relaxed);
        Some(KernelProfile {
            engine: engine.to_string(),
            calls,
            lanes,
            spmm_blocks: self.blocks.load(Relaxed),
            ell_bytes: self.ell_bytes.load(Relaxed),
            er_bytes: self.er_bytes.load(Relaxed),
            meta_bytes: self.meta_bytes.load(Relaxed),
            x_fill_bytes: self.x_fill_bytes.load(Relaxed),
            x_gather_bytes: self.x_gather_bytes.load(Relaxed),
            write_bytes: self.write_bytes.load(Relaxed),
            halo_bytes: 0,
            x_lines: c.x_lines,
            pad_slots: c.pad_slots,
            pad_bytes: c.pad_bytes,
            er_scatter_rows: c.er_scatter_rows,
            flops: c.flops * lanes,
            secs: self.nanos.load(Relaxed) as f64 / 1e9,
        })
    }
}

/// Aggregated observed data movement for one engine (or one sharded
/// fan-out, via [`KernelProfile::merge`]). Byte counters are totals
/// across all recorded calls; `x_lines`/`pad_slots`/`pad_bytes`/
/// `er_scatter_rows` are structural per-engine figures.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct KernelProfile {
    pub engine: String,
    /// Kernel invocations recorded.
    pub calls: u64,
    /// Right-hand sides processed (a plain `spmv` is one lane).
    pub lanes: u64,
    /// SpMM register blocks executed — `lanes / spmm_blocks` is the
    /// observed register-tile reuse of the fused path.
    pub spmm_blocks: u64,
    /// Primary format stream bytes (ELL slice data + u16 cols; the
    /// whole cols+vals stream for CSR engines).
    pub ell_bytes: u64,
    /// ER-tail stream bytes.
    pub er_bytes: u64,
    /// Descriptor bytes (slice/row pointers, `y_idx_er`).
    pub meta_bytes: u64,
    /// Explicit shared-memory x-cache fill bytes.
    pub x_fill_bytes: u64,
    /// Uncached x gather bytes (logical).
    pub x_gather_bytes: u64,
    /// Output-vector write bytes.
    pub write_bytes: u64,
    /// Cross-shard halo gather bytes (sharded engines only).
    pub halo_bytes: u64,
    /// Distinct 64-byte x lines the uncached gathers touch.
    pub x_lines: u64,
    /// Stored slots minus logical nonzeros (format padding).
    pub pad_slots: u64,
    /// Stream bytes wasted on padding per single-lane walk.
    pub pad_bytes: u64,
    /// Rows the ER tail scatters into.
    pub er_scatter_rows: u64,
    /// Useful flops across all lanes.
    pub flops: u64,
    /// Wall-clock seconds inside recorded kernel calls.
    pub secs: f64,
}

impl KernelProfile {
    /// Total observed bytes across all components and calls.
    pub fn total_bytes(&self) -> u64 {
        self.ell_bytes
            + self.er_bytes
            + self.meta_bytes
            + self.x_fill_bytes
            + self.x_gather_bytes
            + self.write_bytes
            + self.halo_bytes
    }

    /// Observed bytes per right-hand side.
    pub fn bytes_per_lane(&self) -> f64 {
        self.total_bytes() as f64 / self.lanes.max(1) as f64
    }

    /// Observed register-tile reuse: lanes served per matrix stream.
    pub fn tile_reuse(&self) -> f64 {
        self.lanes as f64 / self.spmm_blocks.max(1) as f64
    }

    /// Observed arithmetic throughput over the recorded calls.
    pub fn gflops(&self) -> f64 {
        if self.secs <= 0.0 {
            return 0.0;
        }
        self.flops as f64 / self.secs / 1e9
    }

    /// Observed effective bandwidth (logical bytes over wall time).
    pub fn bandwidth_gbs(&self) -> f64 {
        if self.secs <= 0.0 {
            return 0.0;
        }
        self.total_bytes() as f64 / self.secs / 1e9
    }

    /// Fold another engine's profile into this one — used by the
    /// sharded fan-out, where per-shard structural fields (footprint,
    /// padding, scatter width) sum over disjoint shards.
    pub fn merge(&mut self, other: &KernelProfile) {
        self.calls += other.calls;
        self.lanes += other.lanes;
        self.spmm_blocks += other.spmm_blocks;
        self.ell_bytes += other.ell_bytes;
        self.er_bytes += other.er_bytes;
        self.meta_bytes += other.meta_bytes;
        self.x_fill_bytes += other.x_fill_bytes;
        self.x_gather_bytes += other.x_gather_bytes;
        self.write_bytes += other.write_bytes;
        self.halo_bytes += other.halo_bytes;
        self.x_lines += other.x_lines;
        self.pad_slots += other.pad_slots;
        self.pad_bytes += other.pad_bytes;
        self.er_scatter_rows += other.er_scatter_rows;
        self.flops += other.flops;
        self.secs += other.secs;
    }

    pub fn to_json(&self) -> Json {
        obj([
            ("engine", Json::Str(self.engine.clone())),
            ("calls", Json::Num(self.calls as f64)),
            ("lanes", Json::Num(self.lanes as f64)),
            ("spmm_blocks", Json::Num(self.spmm_blocks as f64)),
            ("ell_bytes", Json::Num(self.ell_bytes as f64)),
            ("er_bytes", Json::Num(self.er_bytes as f64)),
            ("meta_bytes", Json::Num(self.meta_bytes as f64)),
            ("x_fill_bytes", Json::Num(self.x_fill_bytes as f64)),
            ("x_gather_bytes", Json::Num(self.x_gather_bytes as f64)),
            ("write_bytes", Json::Num(self.write_bytes as f64)),
            ("halo_bytes", Json::Num(self.halo_bytes as f64)),
            ("x_lines", Json::Num(self.x_lines as f64)),
            ("pad_slots", Json::Num(self.pad_slots as f64)),
            ("pad_bytes", Json::Num(self.pad_bytes as f64)),
            ("er_scatter_rows", Json::Num(self.er_scatter_rows as f64)),
            ("flops", Json::Num(self.flops as f64)),
            ("secs", Json::Num(self.secs)),
        ])
    }
}

/// One component's observed-vs-predicted byte comparison. Observed is
/// normalized per lane; predicted is the simulator's figure for the
/// replayed call.
#[derive(Clone, Debug, PartialEq)]
pub struct ComponentDrift {
    pub component: &'static str,
    pub observed_bytes: f64,
    pub predicted_bytes: f64,
}

impl ComponentDrift {
    /// Symmetric relative gap: |observed − predicted| over the larger
    /// of the two (0 when both are 0), so it stays in [0, 1].
    pub fn rel(&self) -> f64 {
        let base = self.predicted_bytes.max(self.observed_bytes);
        if base <= 0.0 {
            return 0.0;
        }
        (self.observed_bytes - self.predicted_bytes).abs() / base
    }
}

/// The sim-vs-observed cross-check: per-component byte attribution
/// plus the secs gap the calibration exists to close. Built by
/// [`DriftReport::new`] from a [`KernelProfile`] and the
/// [`TrafficReport`] of the same prepared plan.
#[derive(Clone, Debug, PartialEq)]
pub struct DriftReport {
    pub engine: String,
    /// Lanes the observation averaged over.
    pub lanes: u64,
    /// Relative bound past which [`DriftReport::exceeded`] fires.
    pub threshold: f64,
    pub components: Vec<ComponentDrift>,
    /// Observed logical bytes per lane.
    pub observed_bytes: f64,
    /// Predicted logical bytes (component total of the replay).
    pub predicted_bytes: f64,
    /// Predicted sector-granular DRAM bytes — differs from the logical
    /// figure by L2 hits and sector rounding, so a gap here that the
    /// components don't show is cache-model, not stream-model, drift.
    pub predicted_dram_bytes: u64,
    /// Measured wall seconds per lane.
    pub observed_secs: f64,
    /// Simulator seconds, calibrated when a [`Calibration`] was given.
    pub predicted_secs: f64,
    /// Whether `predicted_secs` went through a calibration.
    pub calibrated: bool,
}

impl DriftReport {
    /// Diff `observed` against the replay `predicted` of the same
    /// plan. Observed counters are normalized per lane, so a
    /// single-vector workload compares exactly against the B=1 replay;
    /// fused batch lanes legitimately show *less* observed ELL stream
    /// than the B=1 prediction — that is the register-tile reuse, and
    /// it is attributed to the named `ell-stream` component.
    pub fn new(
        observed: &KernelProfile,
        predicted: &TrafficReport,
        calibration: Option<&Calibration>,
        threshold: f64,
    ) -> DriftReport {
        let lanes = observed.lanes.max(1) as f64;
        let comp = |name: &'static str, obs: u64, pred: u64| ComponentDrift {
            component: name,
            observed_bytes: obs as f64 / lanes,
            predicted_bytes: pred as f64,
        };
        let c = &predicted.components;
        let components = vec![
            comp("ell-stream", observed.ell_bytes, c.ell),
            comp("er-tail", observed.er_bytes, c.er),
            comp("meta", observed.meta_bytes, c.meta),
            comp("x-fill", observed.x_fill_bytes, c.x_fill),
            comp("x-gather", observed.x_gather_bytes, c.x_gather),
            comp("halo", observed.halo_bytes, c.halo),
            comp("write", observed.write_bytes, c.write),
        ];
        let predicted_secs = match calibration {
            Some(cal) => cal.apply(predicted),
            None => predicted.predicted_secs,
        };
        DriftReport {
            engine: observed.engine.clone(),
            lanes: observed.lanes,
            threshold,
            components,
            observed_bytes: observed.total_bytes() as f64 / lanes,
            predicted_bytes: c.total() as f64,
            predicted_dram_bytes: predicted.dram_total_bytes(),
            observed_secs: observed.secs / lanes,
            predicted_secs,
            calibrated: calibration.is_some(),
        }
    }

    /// Relative gap on total logical bytes.
    pub fn bytes_drift(&self) -> f64 {
        ComponentDrift {
            component: "total",
            observed_bytes: self.observed_bytes,
            predicted_bytes: self.predicted_bytes,
        }
        .rel()
    }

    /// Relative gap between observed logical bytes and the simulator's
    /// sector-granular DRAM figure — the acceptance-criterion
    /// comparison; when it exceeds the bound, [`Self::worst_component`]
    /// names the stream responsible.
    pub fn dram_drift(&self) -> f64 {
        ComponentDrift {
            component: "dram",
            observed_bytes: self.observed_bytes,
            predicted_bytes: self.predicted_dram_bytes as f64,
        }
        .rel()
    }

    /// Relative gap on seconds (meaningful once calibrated; the raw
    /// V100 model is not expected to track a CPU host).
    pub fn secs_drift(&self) -> f64 {
        ComponentDrift {
            component: "secs",
            observed_bytes: self.observed_secs,
            predicted_bytes: self.predicted_secs,
        }
        .rel()
    }

    /// Largest per-component relative gap.
    pub fn max_rel_drift(&self) -> f64 {
        self.components.iter().map(|c| c.rel()).fold(0.0, f64::max)
    }

    /// The component with the largest relative gap — the named cause a
    /// drifting prediction is attributed to.
    pub fn worst_component(&self) -> Option<&ComponentDrift> {
        self.components
            .iter()
            .max_by(|a, b| a.rel().total_cmp(&b.rel()))
    }

    /// The scalar a plan's drift provenance records
    /// (`TunedPlan::drift`): the worst relative gap [`Self::exceeded`]
    /// gates on — component bytes, plus calibrated seconds once a
    /// calibration claims to track this host.
    pub fn stamp(&self) -> f64 {
        let mut d = self.max_rel_drift();
        if self.calibrated {
            d = d.max(self.secs_drift());
        }
        d
    }

    /// True when the model has observably drifted: a component's byte
    /// attribution is off by more than the threshold, or — once a
    /// calibration claims to track this host — the calibrated seconds
    /// are. This is the predicate that records a `ModelDrift` health
    /// event and invalidates cached plans.
    pub fn exceeded(&self) -> bool {
        self.stamp() > self.threshold
    }

    pub fn to_json(&self) -> Json {
        obj([
            ("engine", Json::Str(self.engine.clone())),
            ("lanes", Json::Num(self.lanes as f64)),
            ("threshold", Json::Num(self.threshold)),
            ("observed_bytes", Json::Num(self.observed_bytes)),
            ("predicted_bytes", Json::Num(self.predicted_bytes)),
            ("predicted_dram_bytes", Json::Num(self.predicted_dram_bytes as f64)),
            ("observed_secs", Json::Num(self.observed_secs)),
            ("predicted_secs", Json::Num(self.predicted_secs)),
            ("calibrated", Json::Bool(self.calibrated)),
            ("max_rel_drift", Json::Num(self.max_rel_drift())),
            ("exceeded", Json::Bool(self.exceeded())),
            (
                "components",
                Json::Arr(
                    self.components
                        .iter()
                        .map(|c| {
                            obj([
                                ("component", Json::Str(c.component.to_string())),
                                ("observed_bytes", Json::Num(c.observed_bytes)),
                                ("predicted_bytes", Json::Num(c.predicted_bytes)),
                                ("rel", Json::Num(c.rel())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// One measured data point for the calibration fit: the simulator's
/// per-level byte totals for a plan plus the wall seconds a real call
/// over that plan took.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CalSample {
    pub dram_bytes: f64,
    pub l2_bytes: f64,
    pub shm_bytes: f64,
    pub measured_secs: f64,
}

impl CalSample {
    pub fn of(r: &TrafficReport, measured_secs: f64) -> CalSample {
        CalSample {
            dram_bytes: r.dram.total_bytes() as f64,
            l2_bytes: r.l2.total_bytes() as f64,
            shm_bytes: r.shm.read_bytes as f64,
            measured_secs,
        }
    }
}

/// Least-squares per-level secs/byte scales mapping simulated traffic
/// to wall time on the host that actually runs the kernels:
/// `secs ≈ dram·a + l2·b + shm·c + base`. Fit from measured probes
/// ([`Calibration::fit`]), persisted next to plans via the plan
/// store's atomic JSON, and applied where the Heuristic oracle reads
/// `predicted_secs` — an additive refit of the simulator's
/// bottleneck-max model, which a linear fit can approximate because
/// the per-engine mixes keep the level totals distinguishable.
#[derive(Clone, Debug, PartialEq)]
pub struct Calibration {
    /// Seconds per DRAM byte.
    pub dram_secs_per_byte: f64,
    /// Seconds per L2 byte.
    pub l2_secs_per_byte: f64,
    /// Seconds per shared-memory byte.
    pub shm_secs_per_byte: f64,
    /// Fixed per-call overhead (launch/dispatch analogue).
    pub base_secs: f64,
    /// Samples the fit consumed.
    pub samples: usize,
    /// RMS relative residual of the fit over its own samples.
    pub residual: f64,
}

/// Solve a 4×4 linear system by Gaussian elimination with partial
/// pivoting — deterministic, no dependencies. `None` on a (nearly)
/// singular pivot.
fn solve4(mut a: [[f64; 4]; 4], mut b: [f64; 4]) -> Option<[f64; 4]> {
    for col in 0..4 {
        let piv = (col..4)
            .max_by(|&i, &j| a[i][col].abs().total_cmp(&a[j][col].abs()))
            .unwrap();
        if a[piv][col].abs() < 1e-300 {
            return None;
        }
        a.swap(col, piv);
        b.swap(col, piv);
        for row in col + 1..4 {
            let f = a[row][col] / a[col][col];
            for k in col..4 {
                a[row][k] -= f * a[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = [0.0f64; 4];
    for col in (0..4).rev() {
        let mut s = b[col];
        for k in col + 1..4 {
            s -= a[col][k] * x[k];
        }
        x[col] = s / a[col][col];
    }
    Some(x)
}

impl Calibration {
    /// Ridge-damped least squares over `samples`; `None` with fewer
    /// than two samples or a degenerate system. Features are scaled to
    /// unit max before solving (bytes are ~1e6×, secs ~1e-4×, so raw
    /// normal equations would be hopelessly conditioned), and the
    /// coefficients are clamped non-negative so `apply` stays
    /// monotone in traffic.
    pub fn fit(samples: &[CalSample]) -> Option<Calibration> {
        if samples.len() < 2 {
            return None;
        }
        let feats: Vec<[f64; 4]> = samples
            .iter()
            .map(|s| [s.dram_bytes, s.l2_bytes, s.shm_bytes, 1.0])
            .collect();
        let mut scale = [0.0f64; 4];
        for f in &feats {
            for j in 0..4 {
                scale[j] = scale[j].max(f[j].abs());
            }
        }
        for s in &mut scale {
            if *s <= 0.0 {
                *s = 1.0;
            }
        }
        let mut a = [[0.0f64; 4]; 4];
        let mut b = [0.0f64; 4];
        for (f, s) in feats.iter().zip(samples) {
            let fs = [f[0] / scale[0], f[1] / scale[1], f[2] / scale[2], f[3] / scale[3]];
            for i in 0..4 {
                for j in 0..4 {
                    a[i][j] += fs[i] * fs[j];
                }
                b[i] += fs[i] * s.measured_secs;
            }
        }
        // Ridge damping keeps the tiny system solvable when engines
        // share a bottleneck (collinear level totals).
        let lam = 1e-9 * (a[0][0] + a[1][1] + a[2][2] + a[3][3]).max(1e-12);
        for i in 0..4 {
            a[i][i] += lam;
        }
        let x = solve4(a, b)?;
        let coef = [
            (x[0] / scale[0]).max(0.0),
            (x[1] / scale[1]).max(0.0),
            (x[2] / scale[2]).max(0.0),
            (x[3] / scale[3]).max(0.0),
        ];
        let mut rss = 0.0;
        let mut n = 0usize;
        for s in samples {
            if s.measured_secs > 0.0 {
                let pred = coef[0] * s.dram_bytes
                    + coef[1] * s.l2_bytes
                    + coef[2] * s.shm_bytes
                    + coef[3];
                rss += ((pred - s.measured_secs) / s.measured_secs).powi(2);
                n += 1;
            }
        }
        Some(Calibration {
            dram_secs_per_byte: coef[0],
            l2_secs_per_byte: coef[1],
            shm_secs_per_byte: coef[2],
            base_secs: coef[3],
            samples: samples.len(),
            residual: if n > 0 { (rss / n as f64).sqrt() } else { 0.0 },
        })
    }

    /// Calibrated seconds for a simulated report (floored at 1 ps so
    /// score comparisons stay well-defined).
    pub fn apply(&self, r: &TrafficReport) -> f64 {
        (self.dram_secs_per_byte * r.dram.total_bytes() as f64
            + self.l2_secs_per_byte * r.l2.total_bytes() as f64
            + self.shm_secs_per_byte * r.shm.read_bytes as f64
            + self.base_secs)
            .max(1e-12)
    }

    /// The un-fit identity for `dev`: the simulator's own bandwidths,
    /// i.e. `apply` ≈ the additive reading of `predicted_secs`.
    pub fn uncalibrated(dev: &GpuDevice) -> Calibration {
        let shm_bw = dev.shm_bytes_per_cycle * dev.sms as f64 * dev.total_cycles_per_sec();
        Calibration {
            dram_secs_per_byte: 1.0 / dev.hbm_bw,
            l2_secs_per_byte: 1.0 / dev.l2_bw,
            shm_secs_per_byte: 1.0 / shm_bw,
            base_secs: dev.launch_overhead,
            samples: 0,
            residual: 0.0,
        }
    }

    pub fn to_json(&self) -> Json {
        obj([
            ("schema", Json::Str("ehyb-calibration-v1".to_string())),
            ("dram_secs_per_byte", Json::Num(self.dram_secs_per_byte)),
            ("l2_secs_per_byte", Json::Num(self.l2_secs_per_byte)),
            ("shm_secs_per_byte", Json::Num(self.shm_secs_per_byte)),
            ("base_secs", Json::Num(self.base_secs)),
            ("samples", Json::Num(self.samples as f64)),
            ("residual", Json::Num(self.residual)),
        ])
    }

    pub fn from_json(j: &Json) -> crate::Result<Calibration> {
        crate::ensure!(
            j.get("schema").and_then(Json::as_str) == Some("ehyb-calibration-v1"),
            "not an ehyb-calibration-v1 document"
        );
        let num = |k: &str| -> crate::Result<f64> {
            j.get(k).and_then(Json::as_f64).ok_or_else(|| {
                crate::EhybError::Parse(format!("calibration missing numeric field {k:?}"))
            })
        };
        let c = Calibration {
            dram_secs_per_byte: num("dram_secs_per_byte")?,
            l2_secs_per_byte: num("l2_secs_per_byte")?,
            shm_secs_per_byte: num("shm_secs_per_byte")?,
            base_secs: num("base_secs")?,
            samples: num("samples")? as usize,
            residual: num("residual")?,
        };
        crate::ensure!(
            c.dram_secs_per_byte >= 0.0
                && c.l2_secs_per_byte >= 0.0
                && c.shm_secs_per_byte >= 0.0
                && c.base_secs >= 0.0
                && c.residual.is_finite(),
            "calibration coefficients out of range"
        );
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::preprocess::{EhybPlan, PreprocessConfig};
    use crate::sparse::gen::{poisson2d, unstructured_mesh};
    use crate::traffic::{baseline_traffic, ehyb_traffic};

    fn dev() -> GpuDevice {
        GpuDevice::v100()
    }

    fn fixture() -> EhybMatrix<f64> {
        let m = unstructured_mesh::<f64>(40, 40, 0.5, 5);
        EhybPlan::build(&m, &PreprocessConfig::default()).unwrap().matrix
    }

    #[test]
    fn ehyb_cost_matches_replay_components() {
        let e = fixture();
        let cost = CallCost::of_ehyb(&e);
        let r = ehyb_traffic(&e, &dev());
        let c = &r.components;
        assert_eq!(cost.ell_stream, c.ell);
        assert_eq!(cost.er_stream, c.er);
        assert_eq!(cost.meta_block + cost.meta_lane, c.meta);
        assert_eq!(cost.x_fill, c.x_fill);
        assert_eq!(cost.x_gather, c.x_gather);
        assert_eq!(cost.write, c.write);
        assert_eq!(cost.lane_bytes(), c.total());
    }

    #[test]
    fn csr_cost_matches_replay_components() {
        let m = poisson2d::<f64>(24, 24);
        let cost = CallCost::of_csr(&m);
        let r = baseline_traffic(crate::api::EngineKind::CsrVector, &m, &dev());
        let c = &r.components;
        assert_eq!(cost.ell_stream, c.ell);
        assert_eq!(cost.meta_block, c.meta);
        assert_eq!(cost.x_gather, c.x_gather);
        assert_eq!(cost.write, c.write);
    }

    #[test]
    fn x_footprint_counts_distinct_lines_once() {
        // 8 f64 elements per 64-byte line: columns 0..8 share line 0.
        assert_eq!(distinct_x_lines([0usize, 1, 7, 7, 0].into_iter(), 16, 8), 1);
        assert_eq!(distinct_x_lines([0usize, 8, 16].into_iter(), 32, 8), 3);
        assert_eq!(distinct_x_lines(std::iter::empty(), 4, 8), 0);
    }

    #[cfg(feature = "profile")]
    #[test]
    fn record_charges_blocks_and_lanes() {
        let cost = CallCost {
            ell_stream: 100,
            meta_block: 10,
            er_stream: 7,
            meta_lane: 3,
            x_fill: 50,
            x_gather: 5,
            write: 20,
            flops: 11,
            ..CallCost::default()
        };
        let st = ProfileState::new();
        assert!(st.snapshot("e").is_none(), "no calls yet");
        st.record(1, 0.5, || cost);
        st.record(7, 1.5, || cost); // blocks: 4+2+1 → 3
        let p = st.snapshot("e").unwrap();
        assert_eq!((p.calls, p.lanes, p.spmm_blocks), (2, 8, 4));
        assert_eq!(p.ell_bytes, 100 * 4);
        assert_eq!(p.meta_bytes, 10 * 4 + 3 * 8);
        assert_eq!(p.er_bytes, 7 * 8);
        assert_eq!(p.x_fill_bytes, 50 * 8);
        assert_eq!(p.x_gather_bytes, 5 * 8);
        assert_eq!(p.write_bytes, 20 * 8);
        assert_eq!(p.flops, 11 * 8);
        assert!((p.secs - 2.0).abs() < 1e-6);
        assert!((p.tile_reuse() - 2.0).abs() < 1e-12);
        // Width 0 records nothing.
        st.record(0, 9.0, || cost);
        assert_eq!(st.snapshot("e").unwrap().calls, 2);
    }

    #[cfg(not(feature = "profile"))]
    #[test]
    fn recording_is_a_no_op_when_feature_off() {
        let st = ProfileState::new();
        st.record(4, 1.0, CallCost::default);
        assert!(st.snapshot("e").is_none());
        assert!(timer().is_none());
        assert_eq!(elapsed(None), 0.0);
    }

    #[test]
    fn zero_drift_when_observed_equals_replay() {
        let e = fixture();
        let r = ehyb_traffic(&e, &dev());
        let st = ProfileState::new();
        st.record(1, 1e-3, || CallCost::of_ehyb(&e));
        if let Some(p) = st.snapshot("ehyb") {
            let d = DriftReport::new(&p, &r, None, DEFAULT_DRIFT_THRESHOLD);
            assert_eq!(d.max_rel_drift(), 0.0, "{d:?}");
            assert_eq!(d.stamp(), 0.0, "uncalibrated stamp ignores secs");
            assert!(!d.exceeded());
            assert_eq!(d.bytes_drift(), 0.0);
            // Uncalibrated secs never trip the predicate.
            assert!(d.secs_drift() > 0.0);
        }
    }

    #[test]
    fn worst_component_names_an_injected_gap() {
        let e = fixture();
        let r = ehyb_traffic(&e, &dev());
        let st = ProfileState::new();
        st.record(1, 1e-3, || {
            let mut c = CallCost::of_ehyb(&e);
            c.x_gather *= 3; // model the tail gathering 3× the prediction
            c
        });
        if let Some(p) = st.snapshot("ehyb") {
            let d = DriftReport::new(&p, &r, None, 0.05);
            assert!(d.exceeded());
            assert_eq!(d.worst_component().unwrap().component, "x-gather");
            assert!(d.max_rel_drift() > 0.5);
        }
    }

    #[test]
    fn component_rel_is_symmetric_and_bounded() {
        let c = ComponentDrift { component: "c", observed_bytes: 50.0, predicted_bytes: 100.0 };
        let f = ComponentDrift { component: "c", observed_bytes: 100.0, predicted_bytes: 50.0 };
        assert_eq!(c.rel(), f.rel());
        assert!((c.rel() - 0.5).abs() < 1e-12);
        let z = ComponentDrift { component: "c", observed_bytes: 0.0, predicted_bytes: 0.0 };
        assert_eq!(z.rel(), 0.0);
    }

    #[test]
    fn fit_recovers_a_known_linear_model() {
        let truth = [2.0e-12, 5.0e-13, 1.0e-13, 3.0e-6];
        let mut samples = Vec::new();
        for (i, j) in [(1u64, 3u64), (2, 1), (5, 4), (9, 2), (3, 7), (8, 8)] {
            // i·j keeps the three byte features linearly independent so
            // the fit recovers the generating coefficients exactly.
            let (dram, l2, shm) =
                (i as f64 * 1e6, (i * j + 1) as f64 * 2e6, j as f64 * 5e5);
            samples.push(CalSample {
                dram_bytes: dram,
                l2_bytes: l2,
                shm_bytes: shm,
                measured_secs: truth[0] * dram + truth[1] * l2 + truth[2] * shm + truth[3],
            });
        }
        let cal = Calibration::fit(&samples).unwrap();
        assert!(cal.residual < 1e-6, "residual {}", cal.residual);
        for s in &samples {
            let pred = cal.dram_secs_per_byte * s.dram_bytes
                + cal.l2_secs_per_byte * s.l2_bytes
                + cal.shm_secs_per_byte * s.shm_bytes
                + cal.base_secs;
            assert!(
                (pred - s.measured_secs).abs() / s.measured_secs < 1e-6,
                "pred {pred} vs {s:?}"
            );
        }
    }

    #[test]
    fn fit_needs_two_samples_and_survives_collinearity() {
        let one = CalSample { dram_bytes: 1e6, l2_bytes: 2e6, shm_bytes: 0.0, measured_secs: 1e-4 };
        assert!(Calibration::fit(&[one]).is_none());
        // Perfectly collinear samples: ridge damping must still yield
        // a usable (non-NaN, non-negative) fit.
        let col: Vec<CalSample> = (1..=4)
            .map(|k| CalSample {
                dram_bytes: k as f64 * 1e6,
                l2_bytes: k as f64 * 2e6,
                shm_bytes: k as f64 * 1e5,
                measured_secs: k as f64 * 1e-4,
            })
            .collect();
        let cal = Calibration::fit(&col).unwrap();
        assert!(cal.dram_secs_per_byte.is_finite() && cal.dram_secs_per_byte >= 0.0);
        assert!(cal.residual.is_finite());
    }

    #[test]
    fn calibration_json_roundtrip() {
        let cal = Calibration {
            dram_secs_per_byte: 1.25e-11,
            l2_secs_per_byte: 4.5e-13,
            shm_secs_per_byte: 6.0e-14,
            base_secs: 2.5e-6,
            samples: 9,
            residual: 0.125,
        };
        let back = Calibration::from_json(&Json::parse(&cal.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back, cal);
        assert!(Calibration::from_json(&Json::parse("{}").unwrap()).is_err());
        let bad = r#"{"schema":"ehyb-calibration-v1","dram_secs_per_byte":-1,
            "l2_secs_per_byte":0,"shm_secs_per_byte":0,"base_secs":0,
            "samples":2,"residual":0}"#;
        assert!(Calibration::from_json(&Json::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn uncalibrated_apply_tracks_the_sim_model() {
        let e = fixture();
        let r = ehyb_traffic(&e, &dev());
        let cal = Calibration::uncalibrated(&dev());
        // The additive reading is within a small factor of the
        // bottleneck-max model (it sums instead of maxing).
        let add = cal.apply(&r);
        assert!(add >= r.predicted_secs * 0.3 && add <= r.predicted_secs * 3.5, "{add}");
    }

    #[test]
    fn merge_sums_shard_profiles() {
        let mut a = KernelProfile {
            engine: "sharded".into(),
            calls: 2,
            lanes: 2,
            spmm_blocks: 2,
            ell_bytes: 100,
            halo_bytes: 7,
            x_lines: 10,
            flops: 40,
            secs: 0.5,
            ..KernelProfile::default()
        };
        let b = KernelProfile {
            engine: "ehyb-shard".into(),
            calls: 2,
            lanes: 2,
            spmm_blocks: 2,
            ell_bytes: 50,
            halo_bytes: 3,
            x_lines: 4,
            flops: 10,
            secs: 0.25,
            ..KernelProfile::default()
        };
        a.merge(&b);
        assert_eq!(a.engine, "sharded", "merge keeps the aggregate tag");
        assert_eq!((a.calls, a.lanes), (4, 4));
        assert_eq!(a.ell_bytes, 150);
        assert_eq!(a.halo_bytes, 10);
        assert_eq!(a.x_lines, 14);
        assert_eq!(a.flops, 50);
        assert!((a.secs - 0.75).abs() < 1e-12);
    }

    #[test]
    fn profile_json_has_the_gauge_fields() {
        let p = KernelProfile {
            engine: "ehyb".into(),
            calls: 3,
            lanes: 5,
            ell_bytes: 1000,
            secs: 0.5,
            ..KernelProfile::default()
        };
        let j = p.to_json();
        assert_eq!(j.get("engine").unwrap().as_str(), Some("ehyb"));
        assert_eq!(j.get("calls").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("ell_bytes").unwrap().as_usize(), Some(1000));
        // Round-trips through the writer.
        assert!(Json::parse(&j.dump()).is_ok());
    }

    #[test]
    fn solve4_handles_pivoting_and_singularity() {
        // A system that needs a row swap to solve.
        let a = [
            [0.0, 2.0, 0.0, 0.0],
            [1.0, 0.0, 0.0, 0.0],
            [0.0, 0.0, 3.0, 0.0],
            [0.0, 0.0, 0.0, 4.0],
        ];
        let x = solve4(a, [2.0, 1.0, 9.0, 8.0]).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-12);
        assert!((x[1] - 1.0).abs() < 1e-12);
        assert!((x[2] - 3.0).abs() < 1e-12);
        assert!((x[3] - 2.0).abs() < 1e-12);
        assert!(solve4([[0.0; 4]; 4], [1.0; 4]).is_none());
    }
}
