//! Row-sharded execution (ISSUE 4 tentpole): split a CSR matrix into K
//! contiguous row shards whose working sets fit per-core caches, build
//! one prepared engine per shard, and fan SpMV/SpMM out shard-parallel
//! with each shard writing a disjoint row range of `y`.
//!
//! This is the paper's explicit-caching argument applied one level up:
//! EHYB partitions the *input vector* so each partition's x-slice fits
//! the scratchpad; sharding partitions the *matrix rows* so each
//! shard's format + x working set fits a core's private cache — the
//! cache-locality blocking of Akbudak & Aykanat's
//! hypergraph-partitioned SpMV, realized with contiguous row blocks.
//!
//! * [`ShardSpec`] / [`ShardStrategy`] — how many shards and where the
//!   boundaries go ([`ShardPlan`]): nnz-balanced prefix splits, plus a
//!   cache-aware refinement that nudges each boundary to the nearby row
//!   minimizing boundary-crossing entries (the same edge-cut objective
//!   [`crate::partition`] optimizes, restricted to contiguous splits —
//!   pair it with a locality-improving global ordering via
//!   [`crate::api::SpmvContextBuilder::reorder`] ([`crate::reorder`])
//!   so the contiguous boundaries have real locality to find; the
//!   facade reports the cut before/after through
//!   [`crate::api::SpmvContext::reorder_cut_nnz`]).
//! * [`engine::ShardedEngine`] — the [`crate::spmv::SpmvEngine`]
//!   implementation that owns the per-shard engines (each built through
//!   [`crate::api`]'s single engine-construction path) and the
//!   per-shard execution counters.
//!
//! Callers normally reach sharding through the facade:
//! `SpmvContext::builder(m).shards(ShardSpec::Auto).build()?` — see
//! [`crate::api::SpmvContextBuilder::shards`].
//!
//! ## Numerical contract
//!
//! For every engine whose per-row accumulation depends only on that
//! row's entries (csr-scalar, csr-vector, ell, hyb, sellp, csr5 — all
//! verified by `rust/tests/shard.rs` proptests), the sharded result is
//! **bit-identical** to the unsharded engine at every K: a row shard
//! preserves each row's entry order exactly
//! ([`crate::sparse::csr::Csr::row_slice`]), so the same floating-point
//! operations run in the same order. Two engines re-derive a *global*
//! data-dependent layout and therefore re-associate sums when sharded:
//! `merge` (its team grid spans the whole (rows + nnz) path) and `ehyb`
//! (each shard re-partitions its diagonal block, which is the point —
//! shard-local partitions fit shard-local caches). For those two the
//! sharded result is bit-identical at K = 1, deterministic at every K,
//! and agrees with the unsharded engine to roundoff (also proptested).

pub mod engine;

pub use engine::{ShardStat, ShardedEngine};

use crate::sparse::csr::Csr;
use crate::sparse::scalar::Scalar;
use crate::util::par;
use std::ops::Range;

/// How many row shards to build.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardSpec {
    /// One shard per worker thread ([`crate::util::par::num_threads`]).
    Auto,
    /// Exactly this many shards (clamped to `1..=nrows`).
    Count(usize),
}

impl ShardSpec {
    /// Resolve to a concrete shard count for a matrix with `nrows` rows.
    pub fn resolve(self, nrows: usize) -> usize {
        let k = match self {
            ShardSpec::Auto => par::num_threads(),
            ShardSpec::Count(k) => k,
        };
        k.clamp(1, nrows.max(1))
    }
}

/// Where the shard boundaries go.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ShardStrategy {
    /// Contiguous row ranges with (near-)equal nnz — the load-balance
    /// baseline.
    NnzBalanced,
    /// Start from the nnz-balanced boundaries, then move each one to
    /// the nearby row that minimizes boundary-crossing entries (fewer
    /// out-of-shard x accesses / halo nnz) while keeping the nnz
    /// imbalance bounded. The contiguous-split analogue of the
    /// partitioner's edge-cut objective.
    #[default]
    CacheAware,
}

/// A concrete sharding of one matrix: K contiguous, non-empty,
/// covering row ranges.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    ranges: Vec<Range<usize>>,
}

impl ShardPlan {
    /// Plan `k` shards of `m` under `strategy`. `k` is clamped to
    /// `1..=nrows`; every shard is non-empty and the ranges cover
    /// `0..nrows` in order.
    pub fn new<S: Scalar>(m: &Csr<S>, k: usize, strategy: ShardStrategy) -> ShardPlan {
        let n = m.nrows();
        let k = k.clamp(1, n.max(1));
        let mut bounds = nnz_balanced_bounds(m, k);
        if strategy == ShardStrategy::CacheAware && k > 1 {
            refine_bounds_cache_aware(m, &mut bounds);
        }
        let ranges = bounds.windows(2).map(|w| w[0]..w[1]).collect();
        ShardPlan { ranges }
    }

    pub fn num_shards(&self) -> usize {
        self.ranges.len()
    }

    pub fn ranges(&self) -> &[Range<usize>] {
        &self.ranges
    }

    /// Shard index owning row `r`.
    pub fn shard_of(&self, r: usize) -> usize {
        self.ranges.partition_point(|rg| rg.end <= r)
    }

    /// Entries `(i, j)` whose row and column land in different shards —
    /// the cross-shard traffic the cache-aware strategy minimizes
    /// (meaningful for square matrices, where columns index the same
    /// space as rows).
    pub fn cut_nnz<S: Scalar>(&self, m: &Csr<S>) -> usize {
        let mut cut = 0usize;
        for (s, rg) in self.ranges.iter().enumerate() {
            for i in rg.clone() {
                let (cols, _) = m.row(i);
                cut += cols
                    .iter()
                    .filter(|&&c| {
                        let c = c as usize;
                        c < m.nrows() && self.shard_of(c) != s
                    })
                    .count();
            }
        }
        cut
    }

    /// Per-shard count of entries whose column falls outside the
    /// shard's own row range — the halo gathers each shard pays for
    /// (columns beyond the square part never cross a row boundary and
    /// are not counted, matching [`ShardPlan::cut_nnz`]'s convention).
    /// The per-shard breakdown [`crate::traffic::shard_traffic`] prices
    /// in bytes.
    pub fn halo_nnz<S: Scalar>(&self, m: &Csr<S>) -> Vec<usize> {
        self.ranges
            .iter()
            .map(|rg| {
                let mut halo = 0usize;
                for i in rg.clone() {
                    let (cols, _) = m.row(i);
                    halo += cols
                        .iter()
                        .filter(|&&c| {
                            let c = c as usize;
                            c < m.nrows() && !rg.contains(&c)
                        })
                        .count();
                }
                halo
            })
            .collect()
    }
}

/// `k + 1` boundary rows with (near-)equal nnz per shard and at least
/// one row per shard.
fn nnz_balanced_bounds<S: Scalar>(m: &Csr<S>, k: usize) -> Vec<usize> {
    let n = m.nrows();
    let nnz = m.nnz();
    let mut bounds = Vec::with_capacity(k + 1);
    bounds.push(0usize);
    for t in 1..k {
        let target = ((t as u64 * nnz as u64) / k as u64) as u32;
        // First row whose prefix nnz reaches the target.
        let mut b = m.row_ptr.partition_point(|&p| p < target);
        // Non-empty shards: leave at least one row on each side for the
        // shards still to be placed.
        b = b.clamp(bounds[t - 1] + 1, n - (k - t));
        bounds.push(b);
    }
    bounds.push(n);
    bounds
}

/// Move each interior boundary to the row within a small window that
/// minimizes boundary-crossing entries, without starving a shard or
/// shifting more than ~25% of a shard's nnz target. `cross[b]` — the
/// number of entries `(i, j)` with `min(i,j) < b <= max(i,j)` — is
/// computed for every candidate boundary in one O(nnz + n) pass via a
/// difference array, so refinement never rescans the matrix per
/// candidate.
fn refine_bounds_cache_aware<S: Scalar>(m: &Csr<S>, bounds: &mut [usize]) {
    let n = m.nrows();
    let k = bounds.len() - 1;
    if n < 2 {
        return;
    }
    let mut diff = vec![0i64; n + 1];
    for i in 0..n {
        let (cols, _) = m.row(i);
        for &c in cols {
            let c = c as usize;
            if c >= n {
                continue; // rectangular slice: off-square columns never cross a row boundary
            }
            let (lo, hi) = (i.min(c), i.max(c));
            if lo < hi {
                diff[lo + 1] += 1;
                diff[hi + 1] -= 1;
            }
        }
    }
    let mut cross = vec![0i64; n + 1];
    let mut acc = 0i64;
    for b in 0..=n {
        acc += diff[b];
        cross[b] = acc;
    }
    let nnz_budget = (m.nnz() as u64 / (4 * k as u64)).max(1) as i64;
    let window = (n / (8 * k)).max(1);
    for t in 1..k {
        let b0 = bounds[t];
        let lo = (b0.saturating_sub(window)).max(bounds[t - 1] + 1);
        // bounds[t + 1] is still unrefined for the last boundary (= n);
        // keep at least one row for every following shard.
        let hi = (b0 + window).min(bounds[t + 1].saturating_sub(1)).min(n - (k - t));
        let mut best = b0;
        for b in lo..=hi {
            let moved = (m.row_ptr[b] as i64 - m.row_ptr[b0] as i64).abs();
            if moved > nnz_budget {
                continue;
            }
            if cross[b] < cross[best] {
                best = b;
            }
        }
        bounds[t] = best;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::{banded, circuit, poisson2d};

    #[test]
    fn spec_resolution() {
        assert_eq!(ShardSpec::Count(4).resolve(100), 4);
        assert_eq!(ShardSpec::Count(0).resolve(100), 1);
        assert_eq!(ShardSpec::Count(500).resolve(100), 100);
        let auto = ShardSpec::Auto.resolve(1_000_000);
        assert!(auto >= 1 && auto <= 1_000_000);
        assert_eq!(ShardSpec::Auto.resolve(1), 1);
    }

    #[test]
    fn plan_covers_all_rows_non_empty() {
        let m = poisson2d::<f64>(20, 20);
        for strategy in [ShardStrategy::NnzBalanced, ShardStrategy::CacheAware] {
            for k in [1usize, 2, 3, 7, 16, 400, 1000] {
                let plan = ShardPlan::new(&m, k, strategy);
                assert_eq!(plan.num_shards(), k.clamp(1, m.nrows()));
                let mut next = 0usize;
                for rg in plan.ranges() {
                    assert_eq!(rg.start, next, "{strategy:?} k={k}");
                    assert!(rg.end > rg.start, "{strategy:?} k={k}: empty shard");
                    next = rg.end;
                }
                assert_eq!(next, m.nrows());
            }
        }
    }

    #[test]
    fn nnz_balance_is_reasonable() {
        let m = circuit::<f64>(3000, 4, 0.02, 7);
        let plan = ShardPlan::new(&m, 8, ShardStrategy::NnzBalanced);
        let target = m.nnz() / 8;
        for rg in plan.ranges() {
            let nnz: usize = rg.clone().map(|i| m.row_nnz(i)).sum();
            // Within 2x of the target (hub rows are indivisible).
            assert!(nnz <= 2 * target + m.max_row_nnz(), "shard nnz {nnz} vs target {target}");
        }
    }

    #[test]
    fn cache_aware_cut_never_worse_on_banded() {
        // A banded matrix has clean low-cut boundaries near the
        // nnz-balanced ones; the refinement must find (or keep) them.
        let m = banded::<f64>(2000, 8, 0.7, 3);
        for k in [2usize, 4, 8] {
            let bal = ShardPlan::new(&m, k, ShardStrategy::NnzBalanced);
            let aware = ShardPlan::new(&m, k, ShardStrategy::CacheAware);
            assert!(
                aware.cut_nnz(&m) <= bal.cut_nnz(&m),
                "k={k}: aware {} > balanced {}",
                aware.cut_nnz(&m),
                bal.cut_nnz(&m)
            );
        }
    }

    #[test]
    fn shard_of_is_consistent() {
        let m = poisson2d::<f64>(16, 16);
        let plan = ShardPlan::new(&m, 5, ShardStrategy::CacheAware);
        for (s, rg) in plan.ranges().iter().enumerate() {
            assert_eq!(plan.shard_of(rg.start), s);
            assert_eq!(plan.shard_of(rg.end - 1), s);
        }
    }

    #[test]
    fn cross_counts_match_naive_on_small_matrix() {
        let m = poisson2d::<f64>(6, 6);
        let n = m.nrows();
        // Rebuild cross[] the slow way and compare against the plan cut
        // for every 2-way split.
        for b in 1..n {
            let mut naive = 0usize;
            for i in 0..n {
                let (cols, _) = m.row(i);
                for &c in cols {
                    let c = c as usize;
                    let (lo, hi) = (i.min(c), i.max(c));
                    if lo < b && b <= hi {
                        naive += 1;
                    }
                }
            }
            let plan = ShardPlan { ranges: vec![0..b, b..n] };
            assert_eq!(plan.cut_nnz(&m), naive, "boundary {b}");
        }
    }
}
