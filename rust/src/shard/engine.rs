//! The sharded execution engine: K per-shard engines behind one
//! [`SpmvEngine`], fanning `spmv`/`spmv_batch` out over
//! [`crate::util::par`] with each shard writing a disjoint row range of
//! `y` (race-free by construction — the output is split with
//! `split_at_mut` before the fan-out).
//!
//! Per-shard engines are built through [`crate::api::build_engine`],
//! the crate's single engine-construction path:
//!
//! * baseline kinds get the shard's row slice
//!   ([`Csr::row_slice`] — rectangular, full column space, per-row
//!   entry order preserved, so row-local engines stay bit-identical to
//!   the unsharded engine);
//! * [`EngineKind::Ehyb`] gets an [`EhybShard`]: the shard's **square
//!   diagonal block** runs the full EHYB pipeline (partition → reorder
//!   → explicitly-cached format, knobs tunable per shard), and the
//!   **halo** remainder (columns outside the shard) runs as a CSR tail
//!   accumulated on top — the shard-level mirror of EHYB's own
//!   ELL/ER split.

use super::ShardPlan;
use crate::api::batch::{VecBatch, VecBatchMut};
use crate::api::EngineKind;
use crate::preprocess::{EhybPlan, PreprocessConfig, PreprocessTimings};
use crate::sparse::csr::Csr;
use crate::sparse::scalar::Scalar;
use crate::spmv::SpmvEngine;
use crate::telemetry::{Telemetry, TraceId};
use crate::util::par;
use crate::util::pool::VecPool;
use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

/// Per-shard execution counters — the observability surface behind
/// [`crate::harness::report::shard_markdown`]'s per-shard columns.
#[derive(Debug)]
pub struct ShardStat {
    /// Rows this shard owns.
    pub rows: usize,
    /// Nonzeros this shard owns (block + halo for EHYB shards).
    pub nnz: usize,
    /// Preprocessing timings of this shard's EHYB diagonal-block
    /// pipeline (`None` for baseline kinds and pure-halo shards) — the
    /// per-shard provenance that proves a `.shards(Count(k≥2))` EHYB
    /// build ran exactly k block pipelines and no redundant
    /// whole-matrix one (ISSUE 5 satellite).
    pub block_prep: Option<PreprocessTimings>,
    /// Single-vector kernel executions.
    pub spmv_calls: AtomicU64,
    /// Batched kernel executions (fused calls, not lanes).
    pub batch_calls: AtomicU64,
    /// Total batch lanes (columns) processed by batched executions.
    pub lanes: AtomicU64,
}

/// One shard: a contiguous row range and its prepared engine. The
/// engine's `nrows` equals the range length and its `ncols` spans the
/// full column space, so it consumes the whole `x` and produces exactly
/// the shard's slice of `y`.
struct Shard<S: Scalar> {
    range: Range<usize>,
    engine: Arc<dyn SpmvEngine<S>>,
}

/// A row-sharded [`SpmvEngine`]: presents the full matrix's shape while
/// executing every kernel shard-parallel. See the module docs (and
/// [`crate::shard`]) for the bit-identity contract per engine kind.
pub struct ShardedEngine<S: Scalar> {
    shards: Vec<Shard<S>>,
    stats: Vec<ShardStat>,
    nrows: usize,
    ncols: usize,
    nnz: usize,
    /// Batch output staging, pooled **per shard** (shard sizes differ,
    /// so one shared LIFO pool would hand size-mismatched buffers back
    /// and regrow forever) — steady-state `spmv_batch` calls allocate
    /// nothing (ISSUE 5 satellite; the EhybCpu pop/push discipline
    /// applied to the fan-out).
    scratch: Vec<VecPool<S>>,
    /// Set once by the context ([`Self::set_telemetry`]); when present,
    /// every fused batch call records one `shard.kernel(i=K)` span per
    /// shard, parented under whatever span is open on the handle at
    /// call time (the service's `kernel` span) — so per-shard kernel
    /// timing lands inside the request's batch subtree without the
    /// service knowing about shards.
    tel: OnceLock<Telemetry>,
}

impl<S: Scalar> ShardedEngine<S> {
    /// Build one engine per shard of `plan`. `kind` must be concrete
    /// (the facade resolves `Auto` first). For [`EngineKind::Ehyb`],
    /// `shard_overrides[i]` supplies a per-shard config (the tuned
    /// knobs) and, when available, the block's already-built
    /// [`EhybPlan`] so the tuner's (or a cache hit's) preprocessing
    /// pass is reused instead of repeated.
    #[allow(clippy::type_complexity)]
    pub(crate) fn build(
        m: &Csr<S>,
        kind: EngineKind,
        cfg: &PreprocessConfig,
        plan: &ShardPlan,
        shard_overrides: Option<Vec<(PreprocessConfig, Option<EhybPlan<S>>)>>,
    ) -> crate::Result<ShardedEngine<S>> {
        assert_ne!(kind, EngineKind::Auto, "Auto resolves before sharding");
        if let Some(o) = &shard_overrides {
            assert_eq!(o.len(), plan.num_shards(), "one override per shard");
        }
        let mut ov_iter = shard_overrides.map(Vec::into_iter);
        let mut shards = Vec::with_capacity(plan.num_shards());
        let mut stats = Vec::with_capacity(plan.num_shards());
        for rg in plan.ranges() {
            let mut block_prep = None;
            let engine: Arc<dyn SpmvEngine<S>> = if kind == EngineKind::Ehyb {
                let (shard_cfg, prebuilt) = match ov_iter.as_mut().and_then(Iterator::next) {
                    Some((c, p)) => (c, p),
                    None => (cfg.clone(), None),
                };
                let shard = EhybShard::build(m, rg.clone(), &shard_cfg, prebuilt)?;
                block_prep = shard.block_plan().map(|p| p.timings);
                Arc::new(shard)
            } else {
                crate::api::build_engine(kind, &m.row_slice(rg.start, rg.end), None)
            };
            stats.push(ShardStat {
                rows: rg.len(),
                nnz: engine.nnz(),
                block_prep,
                spmv_calls: AtomicU64::new(0),
                batch_calls: AtomicU64::new(0),
                lanes: AtomicU64::new(0),
            });
            shards.push(Shard { range: rg.clone(), engine });
        }
        Ok(ShardedEngine {
            shards,
            stats,
            nrows: m.nrows(),
            ncols: m.ncols(),
            nnz: m.nnz(),
            // Two retained buffers per shard tolerate a pair of
            // concurrent batch callers before reuse starts missing.
            scratch: (0..plan.num_shards()).map(|_| VecPool::new(2)).collect(),
            tel: OnceLock::new(),
        })
    }

    /// Attach the context's [`Telemetry`] handle (first call wins) so
    /// fused batch executions record per-shard kernel spans.
    pub fn set_telemetry(&self, tel: Telemetry) {
        let _ = self.tel.set(tel);
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// The row range each shard owns, in shard order.
    pub fn ranges(&self) -> Vec<Range<usize>> {
        self.shards.iter().map(|s| s.range.clone()).collect()
    }

    /// Per-shard execution counters, in shard order.
    pub fn stats(&self) -> &[ShardStat] {
        &self.stats
    }

    /// Batch-scratch pool misses (allocations or growth). Flat across
    /// repeated same-width `spmv_batch` calls — the zero
    /// steady-state-allocation invariant pinned by
    /// `rust/tests/reorder.rs`.
    pub fn scratch_misses(&self) -> u64 {
        self.scratch.iter().map(VecPool::misses).sum()
    }

    /// Split `y` into the per-shard disjoint row slices (shard order).
    fn split_y<'y>(&self, mut y: &'y mut [S]) -> Vec<&'y mut [S]> {
        let mut parts = Vec::with_capacity(self.shards.len());
        for s in &self.shards {
            let (head, tail) = y.split_at_mut(s.range.len());
            parts.push(head);
            y = tail;
        }
        debug_assert!(y.is_empty());
        parts
    }
}

impl<S: Scalar> SpmvEngine<S> for ShardedEngine<S> {
    fn name(&self) -> &'static str {
        "sharded"
    }

    fn spmv(&self, x: &[S], y: &mut [S]) {
        assert_eq!(x.len(), self.ncols);
        assert_eq!(y.len(), self.nrows);
        let parts = self.split_y(y);
        par::par_for_each(parts, |i, yslice| {
            self.shards[i].engine.spmv(x, yslice);
            self.stats[i].spmv_calls.fetch_add(1, Ordering::Relaxed);
        });
    }

    fn spmv_batch(&self, xs: VecBatch<'_, S>, ys: &mut VecBatchMut<'_, S>) {
        assert_eq!(xs.width(), ys.width(), "batch inputs/outputs disagree");
        assert_eq!(xs.n(), self.ncols);
        assert_eq!(ys.n(), self.nrows);
        let width = xs.width();
        if width == 0 {
            return;
        }
        // Each shard's output rows interleave across the batch columns,
        // so the fused per-shard kernels run into per-shard contiguous
        // scratch (one fused batch per shard) and the disjoint row
        // segments are copied out afterwards. The buffers are pooled
        // (pop/push, like EhybCpu's scratch): every engine fully
        // overwrites its staging rows, so stale contents are fine.
        let mut scratch: Vec<Vec<S>> = self
            .shards
            .iter()
            .zip(&self.scratch)
            .map(|(s, pool)| pool.take(s.range.len() * width, S::ZERO))
            .collect();
        {
            // Capture the enclosing span (the service's `kernel`) once,
            // before the fan-out: the per-shard spans all attach there
            // regardless of which worker thread runs them.
            let parent = self.tel.get().map(|t| (t, t.current_parent()));
            let items: Vec<(usize, &mut Vec<S>)> = scratch.iter_mut().enumerate().collect();
            par::par_for_each(items, |_, (i, buf)| {
                let rows = self.shards[i].range.len();
                let start = parent.map(|(t, _)| t.now_nanos());
                let mut yv = VecBatchMut::new(buf, rows).expect("contiguous shard scratch");
                self.shards[i].engine.spmv_batch(xs, &mut yv);
                if let (Some((t, p)), Some(s)) = (parent, start) {
                    let end = t.now_nanos();
                    t.record_span(format!("shard.kernel(i={i})"), p, TraceId::NONE, s, end);
                }
                self.stats[i].batch_calls.fetch_add(1, Ordering::Relaxed);
                self.stats[i].lanes.fetch_add(width as u64, Ordering::Relaxed);
            });
        }
        for (shard, buf) in self.shards.iter().zip(&scratch) {
            let rows = shard.range.len();
            for b in 0..width {
                ys.col_mut(b)[shard.range.clone()].copy_from_slice(&buf[b * rows..(b + 1) * rows]);
            }
        }
        for (pool, buf) in self.scratch.iter().zip(scratch) {
            pool.put(buf);
        }
    }

    fn nrows(&self) -> usize {
        self.nrows
    }
    fn ncols(&self) -> usize {
        self.ncols
    }
    fn nnz(&self) -> usize {
        self.nnz
    }
    fn format_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.engine.format_bytes()).sum()
    }
    /// Aggregate of the per-shard profiles ([`KernelProfile::merge`]):
    /// byte counters sum over the disjoint shards, and `lanes` counts
    /// per-shard kernel executions (each solve contributes one lane
    /// *per shard*, since every shard runs the full right-hand side).
    ///
    /// [`KernelProfile::merge`]: crate::profile::KernelProfile::merge
    fn kernel_profile(&self) -> Option<crate::profile::KernelProfile> {
        let mut agg: Option<crate::profile::KernelProfile> = None;
        for s in &self.shards {
            if let Some(p) = s.engine.kernel_profile() {
                match &mut agg {
                    Some(a) => a.merge(&p),
                    None => {
                        let mut p = p;
                        p.engine = "sharded".to_string();
                        agg = Some(p);
                    }
                }
            }
        }
        agg
    }
}

/// One EHYB row shard: the square diagonal block behind the full EHYB
/// pipeline plus the halo (out-of-shard columns) as a CSR tail. Per
/// row, the block's explicitly-cached result accumulates first, then
/// the halo entries in CSR order — the shard-level mirror of EHYB's
/// own ELL-then-ER accumulation.
pub struct EhybShard<S: Scalar> {
    /// `None` when the diagonal block has no entries (then the shard is
    /// pure halo and `y` starts from zero).
    block: Option<Arc<dyn SpmvEngine<S>>>,
    /// The preprocessing output of the diagonal block (partition
    /// provenance, timings) — what per-shard tuning searched over.
    block_plan: Option<EhybPlan<S>>,
    halo: Csr<S>,
    range: Range<usize>,
    ncols: usize,
    nnz: usize,
    /// Pooled staging for the batch path's contiguous x-slices
    /// (pop/push; steady-state batch calls allocate nothing).
    xpool: VecPool<S>,
    /// Observed counters of the halo tail (the block engine keeps its
    /// own); folded together in [`SpmvEngine::kernel_profile`].
    halo_profile: crate::profile::ProfileState,
}

impl<S: Scalar> EhybShard<S> {
    /// `prebuilt` is the block's already-built plan (from per-shard
    /// tuning or a plan-cache hit) — when present, preprocessing is not
    /// repeated here.
    pub(crate) fn build(
        m: &Csr<S>,
        range: Range<usize>,
        cfg: &PreprocessConfig,
        prebuilt: Option<EhybPlan<S>>,
    ) -> crate::Result<EhybShard<S>> {
        let (block_csr, halo) = m.diag_block_split(range.start, range.end);
        let nnz = block_csr.nnz() + halo.nnz();
        let (block, block_plan) = if block_csr.nnz() > 0 {
            let plan = match prebuilt {
                Some(p) => p,
                None => EhybPlan::build(&block_csr, cfg)?,
            };
            let engine = crate::api::build_engine(EngineKind::Ehyb, &block_csr, Some(&plan));
            (Some(engine), Some(plan))
        } else {
            (None, None)
        };
        Ok(EhybShard {
            block,
            block_plan,
            halo,
            range,
            ncols: m.ncols(),
            nnz,
            xpool: VecPool::new(2),
            halo_profile: crate::profile::ProfileState::new(),
        })
    }

    /// x-staging pool misses (allocations or growth) — flat across
    /// repeated same-width batch calls.
    pub fn scratch_misses(&self) -> u64 {
        self.xpool.misses()
    }

    /// The diagonal block's preprocessing output, when the block is
    /// non-empty.
    pub fn block_plan(&self) -> Option<&EhybPlan<S>> {
        self.block_plan.as_ref()
    }

    fn halo_accumulate(&self, x: &[S], y: &mut [S]) {
        if self.halo.nnz() == 0 {
            return;
        }
        let t = crate::profile::timer();
        for i in 0..self.halo.nrows() {
            let (cols, vals) = self.halo.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                y[i] = v.mul_add(x[c as usize], y[i]);
            }
        }
        self.halo_profile.record(1, crate::profile::elapsed(t), || {
            crate::profile::CallCost::of_halo(&self.halo)
        });
    }
}

impl<S: Scalar> SpmvEngine<S> for EhybShard<S> {
    fn name(&self) -> &'static str {
        "ehyb-shard"
    }

    fn spmv(&self, x: &[S], y: &mut [S]) {
        assert_eq!(x.len(), self.ncols);
        assert_eq!(y.len(), self.range.len());
        match &self.block {
            Some(engine) => engine.spmv(&x[self.range.clone()], y),
            None => y.fill(S::ZERO),
        }
        self.halo_accumulate(x, y);
    }

    fn spmv_batch(&self, xs: VecBatch<'_, S>, ys: &mut VecBatchMut<'_, S>) {
        assert_eq!(xs.width(), ys.width(), "batch inputs/outputs disagree");
        let rows = self.range.len();
        let width = xs.width();
        if width == 0 {
            return;
        }
        match &self.block {
            Some(engine) => {
                // Stage the shard's x-slices contiguously so the block
                // engine's fused SpMM path (EhybCpu streams its format
                // once per register block) applies across the batch.
                // Pooled + fully overwritten below, so stale contents
                // are fine.
                let mut xbuf = self.xpool.take(rows * width, S::ZERO);
                for b in 0..width {
                    xbuf[b * rows..(b + 1) * rows]
                        .copy_from_slice(&xs.col(b)[self.range.clone()]);
                }
                {
                    let xv = VecBatch::new(&xbuf, rows).expect("contiguous shard batch");
                    engine.spmv_batch(xv, ys);
                }
                self.xpool.put(xbuf);
            }
            None => {
                for b in 0..width {
                    ys.col_mut(b).fill(S::ZERO);
                }
            }
        }
        for b in 0..width {
            self.halo_accumulate(xs.col(b), ys.col_mut(b));
        }
    }

    fn nrows(&self) -> usize {
        self.range.len()
    }
    fn ncols(&self) -> usize {
        self.ncols
    }
    fn nnz(&self) -> usize {
        self.nnz
    }
    fn format_bytes(&self) -> usize {
        let block = self.block.as_ref().map_or(0, |e| e.format_bytes());
        block + self.halo.bytes()
    }
    fn kernel_profile(&self) -> Option<crate::profile::KernelProfile> {
        // The halo tail's gather bytes are reattributed to
        // `halo_bytes` — the component `shard_traffic` names "halo" —
        // while its stream and pointer bytes stay in their usual
        // components.
        let halo = self.halo_profile.snapshot("ehyb-shard").map(|mut h| {
            h.halo_bytes = h.x_gather_bytes;
            h.x_gather_bytes = 0;
            h
        });
        let block = self.block.as_ref().and_then(|e| e.kernel_profile());
        match (block, halo) {
            (Some(mut p), Some(h)) => {
                p.engine = "ehyb-shard".to_string();
                // The tail rides the block's lanes: fold its bytes,
                // footprint, flops and time, not calls/lanes/blocks.
                p.ell_bytes += h.ell_bytes;
                p.meta_bytes += h.meta_bytes;
                p.halo_bytes += h.halo_bytes;
                p.x_lines += h.x_lines;
                p.flops += h.flops;
                p.secs += h.secs;
                Some(p)
            }
            (Some(mut p), None) => {
                p.engine = "ehyb-shard".to_string();
                Some(p)
            }
            (None, halo) => halo,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shard::ShardStrategy;
    use crate::sparse::gen::{poisson2d, unstructured_mesh};
    use crate::util::check::assert_allclose;

    fn cfg() -> PreprocessConfig {
        PreprocessConfig { vec_size_override: Some(32), ..Default::default() }
    }

    fn sharded(m: &Csr<f64>, kind: EngineKind, k: usize) -> ShardedEngine<f64> {
        let plan = ShardPlan::new(m, k, ShardStrategy::CacheAware);
        ShardedEngine::build(m, kind, &cfg(), &plan, None).unwrap()
    }

    #[test]
    fn sharded_csr_scalar_bitwise_matches_unsharded() {
        let m = unstructured_mesh::<f64>(24, 24, 0.5, 9);
        let full = crate::api::build_engine(EngineKind::CsrScalar, &m, None);
        let x: Vec<f64> = (0..m.ncols()).map(|i| ((i * 7 + 1) % 13) as f64 * 0.5 - 3.0).collect();
        let mut y_full = vec![0.0; m.nrows()];
        full.spmv(&x, &mut y_full);
        for k in [1usize, 2, 5, 16] {
            let e = sharded(&m, EngineKind::CsrScalar, k);
            assert_eq!(e.num_shards(), k);
            let mut y = vec![0.0; m.nrows()];
            e.spmv(&x, &mut y);
            assert_eq!(y, y_full, "k={k}");
            assert!(e.stats().iter().all(|s| s.spmv_calls.load(Ordering::Relaxed) == 1));
        }
    }

    #[test]
    fn sharded_ehyb_matches_oracle_and_is_deterministic() {
        let m = unstructured_mesh::<f64>(32, 32, 0.4, 11);
        let x: Vec<f64> = (0..m.ncols()).map(|i| ((i * 3 + 2) % 17) as f64 * 0.25 - 2.0).collect();
        let oracle = m.spmv_f64_oracle(&x);
        for k in [1usize, 3, 8] {
            let e1 = sharded(&m, EngineKind::Ehyb, k);
            let e2 = sharded(&m, EngineKind::Ehyb, k);
            let mut y1 = vec![0.0; m.nrows()];
            let mut y2 = vec![0.0; m.nrows()];
            e1.spmv(&x, &mut y1);
            e2.spmv(&x, &mut y2);
            assert_eq!(y1, y2, "k={k}: sharded EHYB must be deterministic");
            assert_allclose(&y1, &oracle, 1e-10, 1e-10).unwrap();
            assert_eq!(e1.nnz(), m.nnz());
            assert!(e1.format_bytes() > 0);
        }
    }

    #[test]
    fn sharded_batch_bitwise_matches_repeated_spmv() {
        let m = poisson2d::<f64>(18, 18);
        for kind in [EngineKind::Ehyb, EngineKind::CsrScalar, EngineKind::SellP] {
            let e = sharded(&m, kind, 4);
            let width = 3;
            let mut xs = crate::api::BatchBuf::<f64>::zeros(m.ncols(), width);
            for b in 0..width {
                for i in 0..m.ncols() {
                    xs.col_mut(b)[i] = ((i * 5 + b * 7 + 1) % 11) as f64 * 0.5 - 2.5;
                }
            }
            let mut ys = crate::api::BatchBuf::<f64>::zeros(m.nrows(), width);
            {
                let mut yv = ys.view_mut();
                e.spmv_batch(xs.view(), &mut yv);
            }
            for b in 0..width {
                let mut y1 = vec![0.0; m.nrows()];
                e.spmv(xs.col(b), &mut y1);
                assert_eq!(ys.col(b), &y1[..], "{kind:?} lane {b}");
            }
            let lanes: u64 = e.stats().iter().map(|s| s.lanes.load(Ordering::Relaxed)).sum();
            assert_eq!(lanes, (width * e.num_shards()) as u64);
        }
    }

    #[test]
    fn ehyb_shard_with_empty_block_is_pure_halo() {
        use crate::sparse::coo::Coo;
        // Rows 0..2 have entries only in columns >= 2: the diagonal
        // block of shard 0..2 is empty and everything is halo.
        let mut coo = Coo::<f64>::new(4, 4);
        coo.push(0, 2, 1.0);
        coo.push(0, 3, 2.0);
        coo.push(1, 3, 3.0);
        coo.push(2, 2, 4.0);
        coo.push(3, 3, 5.0);
        let m = coo.to_csr();
        let shard = EhybShard::build(&m, 0..2, &cfg(), None).unwrap();
        assert!(shard.block_plan().is_none());
        assert_eq!(shard.nnz(), 3);
        let x = [1.0, 10.0, 100.0, 1000.0];
        let mut y = [7.0, 7.0]; // stale values must be overwritten
        shard.spmv(&x, &mut y);
        assert_eq!(y, [100.0 + 2000.0, 3000.0]);
    }

    #[test]
    fn batch_scratch_pools_reach_steady_state() {
        // ISSUE 5 satellite: after the first fused batch, repeated
        // batch calls must not allocate — neither the sharded fan-out's
        // staging buffers nor the EHYB shards' x-slice staging.
        let m = poisson2d::<f64>(16, 16);
        for kind in [EngineKind::Ehyb, EngineKind::CsrScalar] {
            let e = sharded(&m, kind, 3);
            let width = 4;
            let mut xs = crate::api::BatchBuf::<f64>::zeros(m.ncols(), width);
            for b in 0..width {
                for i in 0..m.ncols() {
                    xs.col_mut(b)[i] = ((i * 3 + b * 5 + 1) % 13) as f64 * 0.5 - 3.0;
                }
            }
            let mut ys = crate::api::BatchBuf::<f64>::zeros(m.nrows(), width);
            {
                let mut yv = ys.view_mut();
                e.spmv_batch(xs.view(), &mut yv);
            }
            let after_first = e.scratch_misses();
            assert!(after_first > 0, "{kind:?}: first call must populate the pools");
            for _ in 0..8 {
                let mut yv = ys.view_mut();
                e.spmv_batch(xs.view(), &mut yv);
            }
            assert_eq!(
                e.scratch_misses(),
                after_first,
                "{kind:?}: steady-state batch calls must not allocate"
            );
        }
    }

    #[test]
    fn ehyb_shard_x_staging_is_pooled() {
        let m = poisson2d::<f64>(12, 12);
        let shard = EhybShard::build(&m, 24..96, &cfg(), None).unwrap();
        let width = 3;
        let mut xs = crate::api::BatchBuf::<f64>::zeros(m.ncols(), width);
        for b in 0..width {
            for i in 0..m.ncols() {
                xs.col_mut(b)[i] = ((i + b * 7) % 11) as f64 * 0.25 - 1.0;
            }
        }
        let mut ys = crate::api::BatchBuf::<f64>::zeros(shard.nrows(), width);
        {
            let mut yv = ys.view_mut();
            shard.spmv_batch(xs.view(), &mut yv);
        }
        let after_first = shard.scratch_misses();
        for _ in 0..8 {
            let mut yv = ys.view_mut();
            shard.spmv_batch(xs.view(), &mut yv);
        }
        assert_eq!(shard.scratch_misses(), after_first);
    }

    #[test]
    fn ehyb_shards_record_block_preprocessing_timings() {
        let m = unstructured_mesh::<f64>(24, 24, 0.4, 7);
        let e = sharded(&m, EngineKind::Ehyb, 4);
        // Every shard with a non-empty diagonal block carries its own
        // pipeline timings; baseline shards never do.
        let with_prep = e.stats().iter().filter(|s| s.block_prep.is_some()).count();
        assert_eq!(with_prep, 4, "each EHYB shard runs its own block pipeline");
        assert!(e.stats().iter().all(|s| s.block_prep.map_or(true, |t| t.reorder_secs > 0.0)));
        let base = sharded(&m, EngineKind::Hyb, 4);
        assert!(base.stats().iter().all(|s| s.block_prep.is_none()));
    }

    #[test]
    fn batch_records_per_shard_kernel_spans_under_open_parent() {
        let m = poisson2d::<f64>(16, 16);
        let e = sharded(&m, EngineKind::Ehyb, 3);
        let tel = Telemetry::with_fake_clock();
        e.set_telemetry(tel.clone());
        let width = 2;
        let xs = crate::api::BatchBuf::<f64>::zeros(m.ncols(), width);
        let mut ys = crate::api::BatchBuf::<f64>::zeros(m.nrows(), width);
        {
            let _kernel = tel.span("kernel");
            let mut yv = ys.view_mut();
            e.spmv_batch(xs.view(), &mut yv);
        }
        let snap = tel.snapshot();
        let kernel = snap.spans.iter().find(|s| s.name == "kernel").unwrap();
        let shard_spans: Vec<_> =
            snap.spans.iter().filter(|s| s.name.starts_with("shard.kernel")).collect();
        assert_eq!(shard_spans.len(), 3);
        for s in &shard_spans {
            assert_eq!(s.parent, kernel.id, "{} must nest under the kernel span", s.name);
            assert!(s.end_nanos > s.start_nanos);
        }
        // A second telemetry attach is ignored (first wins), and an
        // un-attached engine records nothing.
        e.set_telemetry(Telemetry::with_fake_clock());
        let e2 = sharded(&m, EngineKind::Ehyb, 2);
        let mut yv = ys.view_mut();
        e2.spmv_batch(xs.view(), &mut yv);
    }

    #[test]
    fn shard_stats_shape() {
        let m = poisson2d::<f64>(16, 16);
        let e = sharded(&m, EngineKind::Hyb, 4);
        assert_eq!(e.stats().len(), 4);
        assert_eq!(e.stats().iter().map(|s| s.rows).sum::<usize>(), m.nrows());
        assert_eq!(e.stats().iter().map(|s| s.nnz).sum::<usize>(), m.nnz());
        assert_eq!(e.ranges().len(), 4);
    }
}
