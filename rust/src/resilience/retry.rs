//! Bounded exponential backoff with deterministic jitter for the
//! service client. The policy decides *which* errors are worth
//! retrying: only transient serving faults (`Overloaded` backpressure
//! and `EngineFault` quarantines) — never dimension, parse, or
//! validation errors, which no amount of retrying can fix.

use crate::api::error::EhybError;
use crate::util::prng::Xoshiro256;
use std::time::Duration;

/// Retry schedule for `SpmvClient::spmv_with_retry`: attempt `k`
/// (0-based) sleeps `min(base_delay · 2ᵏ, max_delay)` scaled by a
/// deterministic jitter factor in `[0.5, 1.0)` drawn from a
/// [`Xoshiro256`] seeded with [`Self::seed`] — reproducible backoff
/// traces for the chaos suite, no thundering herd in production.
#[derive(Clone, Debug)]
pub struct RetryPolicy {
    /// Total attempts including the first (≥ 1; clamped at use).
    pub max_attempts: usize,
    /// Backoff base: the sleep after the first failed attempt.
    pub base_delay: Duration,
    /// Cap on any single sleep.
    pub max_delay: Duration,
    /// Seed of the jitter PRNG.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            max_attempts: 4,
            base_delay: Duration::from_millis(1),
            max_delay: Duration::from_millis(100),
            seed: 0x5eed,
        }
    }
}

impl RetryPolicy {
    /// Whether `e` is transient and worth retrying under this policy.
    pub fn retries(&self, e: &EhybError) -> bool {
        matches!(e, EhybError::Overloaded { .. } | EhybError::EngineFault(_))
    }

    /// Jittered sleep before retrying after failed attempt `attempt`
    /// (0-based). Pass the policy's own PRNG so successive delays walk
    /// the deterministic jitter sequence.
    pub fn delay(&self, attempt: usize, rng: &mut Xoshiro256) -> Duration {
        let exp = 1u32 << attempt.min(20) as u32;
        let raw = self.base_delay.saturating_mul(exp).min(self.max_delay);
        raw.mul_f64(rng.range_f64(0.5, 1.0))
    }

    /// Worst-case total sleep across all retries (the budget a caller
    /// is signing up for).
    pub fn max_total_delay(&self) -> Duration {
        let mut total = Duration::ZERO;
        for attempt in 0..self.max_attempts.saturating_sub(1) {
            let exp = 1u32 << attempt.min(20) as u32;
            total += self.base_delay.saturating_mul(exp).min(self.max_delay);
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retries_only_transient_errors() {
        let p = RetryPolicy::default();
        assert!(p.retries(&EhybError::Overloaded { queue_depth: 4 }));
        assert!(p.retries(&EhybError::EngineFault("boom".into())));
        assert!(!p.retries(&EhybError::DimensionMismatch { what: "x", expected: 4, got: 3 }));
        assert!(!p.retries(&EhybError::Parse("bad".into())));
        assert!(!p.retries(&EhybError::ServiceStopped));
        assert!(!p.retries(&EhybError::DeadlineExceeded));
    }

    #[test]
    fn delays_grow_exponentially_and_cap() {
        let p = RetryPolicy {
            max_attempts: 8,
            base_delay: Duration::from_millis(10),
            max_delay: Duration::from_millis(45),
            seed: 1,
        };
        let mut rng = Xoshiro256::new(p.seed);
        let d0 = p.delay(0, &mut rng);
        let d3 = p.delay(3, &mut rng);
        // Jitter is in [0.5, 1.0): attempt 0 ∈ [5, 10) ms, attempt 3
        // capped at 45 ms then jittered into [22.5, 45) ms.
        assert!(d0 >= Duration::from_micros(4990) && d0 < Duration::from_millis(10), "{d0:?}");
        assert!(d3 >= Duration::from_micros(22490) && d3 < Duration::from_millis(45), "{d3:?}");
    }

    #[test]
    fn jitter_is_deterministic_per_seed() {
        let p = RetryPolicy::default();
        let mut a = Xoshiro256::new(p.seed);
        let mut b = Xoshiro256::new(p.seed);
        for attempt in 0..5 {
            assert_eq!(p.delay(attempt, &mut a), p.delay(attempt, &mut b));
        }
    }

    #[test]
    fn max_total_delay_bounds_the_schedule() {
        let p = RetryPolicy {
            max_attempts: 4,
            base_delay: Duration::from_millis(10),
            max_delay: Duration::from_millis(25),
            seed: 0,
        };
        // Sleeps: 10 + 20 + 25 (capped) = 55 ms before jitter (jitter
        // only shrinks them).
        assert_eq!(p.max_total_delay(), Duration::from_millis(55));
    }
}
