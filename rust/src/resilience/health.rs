//! Degradation bookkeeping for a `SpmvContext`: every downgrade the
//! facade performs on the caller's behalf (EHYB build failure → csr-
//! vector engine, solver breakdown → preconditioned restart, guarded
//! non-finite values) is counted here and surfaced by `ctx.health()` —
//! a context never degrades silently.

use crate::telemetry::TraceId;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Shared, thread-safe degradation counters. Lives behind an `Arc` in
/// the context; snapshot it with [`Health::report`].
#[derive(Debug, Default)]
pub struct Health {
    engine_fallbacks: AtomicU64,
    solver_restarts: AtomicU64,
    nonfinite_outputs: AtomicU64,
    rejected_inputs: AtomicU64,
    model_drifts: AtomicU64,
    /// Human-readable event log (one line per degradation, tagged with
    /// the request trace that triggered it — 0 when none was in
    /// scope), capped so a long-running degraded service cannot grow
    /// without bound.
    events: Mutex<Vec<(String, u64)>>,
}

/// Cap on recorded event lines; counters keep counting past it.
const MAX_EVENTS: usize = 64;

impl Health {
    fn push_event(&self, line: String, trace: TraceId) {
        if let Ok(mut ev) = self.events.lock() {
            if ev.len() < MAX_EVENTS {
                ev.push((line, trace.0));
            }
        }
    }

    /// The requested engine could not be built; a baseline serves
    /// instead.
    pub fn record_engine_fallback(&self, detail: impl Into<String>) {
        self.record_engine_fallback_traced(detail, TraceId::NONE);
    }

    /// [`Self::record_engine_fallback`] tagged with the in-scope trace.
    pub fn record_engine_fallback_traced(&self, detail: impl Into<String>, trace: TraceId) {
        self.engine_fallbacks.fetch_add(1, Ordering::Relaxed);
        self.push_event(format!("engine fallback: {}", detail.into()), trace);
    }

    /// A broken-down/diverged solve was restarted with a diagonal-
    /// preconditioned BiCGSTAB.
    pub fn record_solver_restart(&self, detail: impl Into<String>) {
        self.record_solver_restart_traced(detail, TraceId::NONE);
    }

    /// [`Self::record_solver_restart`] tagged with the solve's trace.
    pub fn record_solver_restart_traced(&self, detail: impl Into<String>, trace: TraceId) {
        self.solver_restarts.fetch_add(1, Ordering::Relaxed);
        self.push_event(format!("solver restart: {}", detail.into()), trace);
    }

    /// An output guard observed a non-finite engine result.
    pub fn record_nonfinite_output(&self, detail: impl Into<String>) {
        self.record_nonfinite_output_traced(detail, TraceId::NONE);
    }

    /// [`Self::record_nonfinite_output`] tagged with the request trace.
    pub fn record_nonfinite_output_traced(&self, detail: impl Into<String>, trace: TraceId) {
        self.nonfinite_outputs.fetch_add(1, Ordering::Relaxed);
        self.push_event(format!("non-finite output: {}", detail.into()), trace);
    }

    /// An input guard rejected a non-finite request.
    pub fn record_rejected_input(&self, detail: impl Into<String>) {
        self.record_rejected_input_traced(detail, TraceId::NONE);
    }

    /// [`Self::record_rejected_input`] tagged with the request trace.
    pub fn record_rejected_input_traced(&self, detail: impl Into<String>, trace: TraceId) {
        self.rejected_inputs.fetch_add(1, Ordering::Relaxed);
        self.push_event(format!("rejected input: {}", detail.into()), trace);
    }

    /// `ctx.observe_drift()` found the observed kernel traffic outside
    /// the drift bound of the cost model that picked the engine — the
    /// plan's provenance is stale, not the execution.
    pub fn record_model_drift(&self, detail: impl Into<String>) {
        self.record_model_drift_traced(detail, TraceId::NONE);
    }

    /// [`Self::record_model_drift`] tagged with the in-scope trace.
    pub fn record_model_drift_traced(&self, detail: impl Into<String>, trace: TraceId) {
        self.model_drifts.fetch_add(1, Ordering::Relaxed);
        self.push_event(format!("model drift: {}", detail.into()), trace);
    }

    /// The event log with trace tags, oldest first — what
    /// `SpmvContext::telemetry_snapshot` folds into the telemetry
    /// snapshot's `health` section.
    pub fn events_traced(&self) -> Vec<(String, u64)> {
        self.events.lock().map(|ev| ev.clone()).unwrap_or_default()
    }

    /// Consistent snapshot of the counters and event log.
    pub fn report(&self) -> HealthReport {
        HealthReport {
            engine_fallbacks: self.engine_fallbacks.load(Ordering::Relaxed),
            solver_restarts: self.solver_restarts.load(Ordering::Relaxed),
            nonfinite_outputs: self.nonfinite_outputs.load(Ordering::Relaxed),
            rejected_inputs: self.rejected_inputs.load(Ordering::Relaxed),
            model_drifts: self.model_drifts.load(Ordering::Relaxed),
            events: self
                .events
                .lock()
                .map(|ev| ev.iter().map(|(line, _)| line.clone()).collect())
                .unwrap_or_default(),
        }
    }
}

/// Point-in-time snapshot of a context's [`Health`].
#[derive(Clone, Debug, Default)]
pub struct HealthReport {
    /// EHYB build failures downgraded to a baseline engine.
    pub engine_fallbacks: u64,
    /// Broken-down solves retried with a preconditioned restart.
    pub solver_restarts: u64,
    /// Non-finite engine outputs observed by a guard.
    pub nonfinite_outputs: u64,
    /// Non-finite inputs rejected by a guard.
    pub rejected_inputs: u64,
    /// Observed kernel traffic drifted past the tuning oracle's bound.
    pub model_drifts: u64,
    /// One line per degradation, oldest first (capped).
    pub events: Vec<String>,
}

impl HealthReport {
    /// True when nothing was ever degraded, restarted, or guarded out.
    pub fn healthy(&self) -> bool {
        self.engine_fallbacks == 0
            && self.solver_restarts == 0
            && self.nonfinite_outputs == 0
            && self.rejected_inputs == 0
            && self.model_drifts == 0
    }

    /// True when the context is serving a different engine than
    /// requested.
    pub fn degraded(&self) -> bool {
        self.engine_fallbacks > 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_health_is_healthy() {
        let h = Health::default();
        let rep = h.report();
        assert!(rep.healthy() && !rep.degraded());
        assert!(rep.events.is_empty());
    }

    #[test]
    fn records_show_up_in_report() {
        let h = Health::default();
        h.record_engine_fallback("ehyb plan failed; csr-vector serving");
        h.record_solver_restart("cg breakdown at iter 3");
        h.record_nonfinite_output("spmv y[2]");
        h.record_rejected_input("x[7] is NaN");
        h.record_model_drift("x-gather drifted 0.31 > 0.15");
        let rep = h.report();
        assert!(!rep.healthy() && rep.degraded());
        assert_eq!(
            (
                rep.engine_fallbacks,
                rep.solver_restarts,
                rep.nonfinite_outputs,
                rep.rejected_inputs,
                rep.model_drifts
            ),
            (1, 1, 1, 1, 1)
        );
        assert_eq!(rep.events.len(), 5);
        assert!(rep.events[0].contains("csr-vector"));
        assert!(rep.events[4].starts_with("model drift: "));
    }

    #[test]
    fn model_drift_alone_is_unhealthy_but_not_degraded() {
        let h = Health::default();
        h.record_model_drift_traced("observed bytes 1.4x predicted", TraceId(7));
        let rep = h.report();
        assert!(!rep.healthy(), "drift must surface through healthy()");
        assert!(!rep.degraded(), "drift does not change the serving engine");
        assert_eq!(rep.model_drifts, 1);
        assert_eq!(h.events_traced()[0].1, 7);
    }

    #[test]
    fn event_log_is_capped_but_counters_keep_counting() {
        let h = Health::default();
        for i in 0..(MAX_EVENTS + 10) {
            h.record_nonfinite_output(format!("y[{i}]"));
        }
        let rep = h.report();
        assert_eq!(rep.events.len(), MAX_EVENTS);
        assert_eq!(rep.nonfinite_outputs, (MAX_EVENTS + 10) as u64);
    }

    #[test]
    fn traced_records_tag_events_and_untraced_records_tag_zero() {
        let h = Health::default();
        h.record_solver_restart_traced("cg breakdown at iter 3", TraceId(42));
        h.record_engine_fallback("no trace in scope");
        let traced = h.events_traced();
        assert_eq!(traced.len(), 2);
        assert_eq!(traced[0].1, 42);
        assert!(traced[0].0.contains("solver restart"));
        assert_eq!(traced[1].1, 0);
        // The plain report is unchanged by the tagging: same lines,
        // same order, no trace noise in the strings.
        let rep = h.report();
        assert_eq!(rep.events, vec![traced[0].0.clone(), traced[1].0.clone()]);
        assert!(!rep.events[0].contains("42"));
    }
}
