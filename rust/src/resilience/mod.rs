//! Single-node resilience layer: the ingress contract the distributed
//! serving tier inherits per replica. Four cooperating pieces:
//!
//! - **Panic isolation** lives in [`crate::coordinator::service`]: a
//!   fused batch that panics maps to [`crate::EhybError::EngineFault`]
//!   for exactly the requests in that batch, the engine is respawned,
//!   and the service keeps serving.
//! - **Deadlines + retry** — requests may carry a drain-time deadline
//!   ([`crate::EhybError::DeadlineExceeded`] without occupying kernel
//!   width), and [`RetryPolicy`] drives
//!   `SpmvClient::spmv_with_retry`: bounded exponential backoff with
//!   deterministic [`crate::util::prng`]-seeded jitter, retrying only
//!   transient faults (`Overloaded` / `EngineFault`).
//! - **Degraded mode** — `SpmvContext::builder().fallback(true)`
//!   downgrades EHYB build failures to the csr-vector engine and
//!   retries broken-down solves once with a Jacobi-preconditioned
//!   BiCGSTAB; every downgrade is recorded in [`Health`], surfaced by
//!   `ctx.health()`. [`GuardLevel`] adds optional non-finite input
//!   rejection / output monitoring.
//! - **Deterministic fault injection** — [`FaultPlan`] /
//!   [`FaultInjector`] seed reproducible engine panics, NaN inputs,
//!   torn plan-cache entries, and queue saturation for the chaos suite
//!   (`rust/tests/resilience.rs`) and the `chaos` CLI subcommand.
//!
//! Every injected fault must map to a typed error or a recorded
//! recovery — never a hang, an escaping panic, or a silently wrong `y`.

pub mod fault;
pub mod health;
pub mod retry;

pub use fault::{FaultInjector, FaultPlan};
pub use health::{Health, HealthReport};
pub use retry::RetryPolicy;

/// Non-finite input/output policy of a `SpmvContext`.
///
/// `Off` adds zero cost to the hot path (no scans); `Monitor` scans
/// engine *outputs* and records non-finite results in [`Health`]
/// without changing any return value; `Reject` additionally scans
/// *inputs* before executing and returns
/// [`crate::EhybError::NonFinite`] — the strictest contract, for
/// ingress boundaries where one NaN would silently poison every
/// downstream iterate.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum GuardLevel {
    /// No scanning (the default; identical to pre-0.6 behavior).
    #[default]
    Off,
    /// Scan outputs; record non-finite results in [`Health`].
    Monitor,
    /// Reject non-finite inputs with a typed error (also monitors
    /// outputs).
    Reject,
}

impl GuardLevel {
    /// Whether outputs should be scanned after the engine runs.
    pub fn monitors(self) -> bool {
        !matches!(self, GuardLevel::Off)
    }

    /// Whether inputs should be scanned (and rejected) before the
    /// engine runs.
    pub fn rejects(self) -> bool {
        matches!(self, GuardLevel::Reject)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn guard_levels_nest() {
        assert!(!GuardLevel::Off.monitors() && !GuardLevel::Off.rejects());
        assert!(GuardLevel::Monitor.monitors() && !GuardLevel::Monitor.rejects());
        assert!(GuardLevel::Reject.monitors() && GuardLevel::Reject.rejects());
        assert_eq!(GuardLevel::default(), GuardLevel::Off);
    }
}
