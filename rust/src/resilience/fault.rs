//! Deterministic fault injection: a seeded [`FaultPlan`] describes
//! *which* faults to inject (engine panic on the Nth kernel call, NaN
//! poisoning of the Nth input, a torn plan-cache entry, queue
//! saturation depth) and a [`FaultInjector`] carries the shared call
//! counter that triggers them. The same seed always produces the same
//! plan and the same fault schedule, so the chaos suite and the `chaos`
//! CLI subcommand are bit-reproducible.

use crate::coordinator::service::BatchKernel;
use crate::runtime::json::{obj, Json};
use crate::sparse::scalar::Scalar;
use crate::util::prng::Xoshiro256;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// A seeded, JSON-serializable fault schedule. Call indices are
/// 1-based ("panic on the 2nd kernel call"); `None` disables that
/// fault class.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultPlan {
    /// Seed the plan was derived from (also seeds any jitter consumers
    /// that want to correlate with the plan).
    pub seed: u64,
    /// Kernel call (1-based) that panics inside the engine.
    pub panic_on_call: Option<u64>,
    /// Input-preparation call (1-based) whose `x` gets one NaN planted.
    pub nan_on_call: Option<u64>,
    /// Truncate a plan-cache entry to this many bytes (torn write).
    pub torn_cache_bytes: Option<u64>,
    /// How many requests the saturation drill floods at a depth-1
    /// queue (≥ 2 guarantees at least one shed).
    pub saturate_requests: u64,
}

impl FaultPlan {
    /// Derive every fault deterministically from `seed`.
    pub fn from_seed(seed: u64) -> Self {
        let mut rng = Xoshiro256::new(seed);
        Self {
            seed,
            panic_on_call: Some(1 + rng.next_below(4) as u64),
            nan_on_call: Some(1 + rng.next_below(4) as u64),
            torn_cache_bytes: Some(1 + rng.next_below(24) as u64),
            saturate_requests: 2 + rng.next_below(6) as u64,
        }
    }

    pub fn to_json(&self) -> Json {
        let opt = |v: Option<u64>| match v {
            Some(n) => Json::Num(n as f64),
            None => Json::Null,
        };
        obj([
            ("seed", Json::Num(self.seed as f64)),
            ("panic_on_call", opt(self.panic_on_call)),
            ("nan_on_call", opt(self.nan_on_call)),
            ("torn_cache_bytes", opt(self.torn_cache_bytes)),
            ("saturate_requests", Json::Num(self.saturate_requests as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> crate::Result<Self> {
        let num = |key: &str| -> crate::Result<u64> {
            j.get(key)
                .and_then(Json::as_f64)
                .map(|n| n as u64)
                .ok_or_else(|| crate::EhybError::Parse(format!("fault plan: missing {key}")))
        };
        let opt = |key: &str| -> crate::Result<Option<u64>> {
            match j.get(key) {
                None | Some(Json::Null) => Ok(None),
                Some(v) => v
                    .as_f64()
                    .map(|n| Some(n as u64))
                    .ok_or_else(|| crate::EhybError::Parse(format!("fault plan: bad {key}"))),
            }
        };
        Ok(Self {
            seed: num("seed")?,
            panic_on_call: opt("panic_on_call")?,
            nan_on_call: opt("nan_on_call")?,
            torn_cache_bytes: opt("torn_cache_bytes")?,
            saturate_requests: num("saturate_requests")?,
        })
    }
}

/// Shared trigger state for one [`FaultPlan`]: a call counter the test
/// rig advances once per kernel call (or per prepared input). Cheap to
/// clone — clones share the counter.
#[derive(Clone, Debug)]
pub struct FaultInjector {
    plan: FaultPlan,
    calls: Arc<AtomicU64>,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> Self {
        Self { plan, calls: Arc::new(AtomicU64::new(0)) }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Advance the shared counter; returns the 1-based index of this
    /// call.
    pub fn next_call(&self) -> u64 {
        self.calls.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Calls observed so far.
    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    /// Whether call number `call` is scheduled to panic.
    pub fn should_panic(&self, call: u64) -> bool {
        self.plan.panic_on_call == Some(call)
    }

    /// Plant one NaN in `x` if call number `call` is scheduled for NaN
    /// poisoning; the poisoned index is derived from the seed so it is
    /// reproducible. Returns the poisoned index.
    pub fn poison<S: Scalar>(&self, call: u64, x: &mut [S]) -> Option<usize> {
        if self.plan.nan_on_call != Some(call) || x.is_empty() {
            return None;
        }
        let idx = Xoshiro256::new(self.plan.seed ^ call).next_below(x.len());
        x[idx] = S::from_f64(f64::NAN);
        Some(idx)
    }

    /// Wrap a batched kernel so the scheduled call panics (the panic
    /// fires *inside* the kernel, where the service's isolation must
    /// catch it). All other calls pass straight through.
    pub fn wrap_kernel<S: Scalar>(&self, mut inner: BatchKernel<S>) -> BatchKernel<S> {
        let inj = self.clone();
        Box::new(move |xs, ys| {
            let call = inj.next_call();
            if inj.should_panic(call) {
                panic!("injected engine fault on kernel call {call}");
            }
            inner(xs, ys)
        })
    }

    /// Tear a plan-cache entry (or any file): truncate it to the plan's
    /// `torn_cache_bytes`, simulating a write interrupted mid-file.
    /// Returns `Ok(false)` when the plan does not schedule tearing.
    pub fn tear_file(&self, path: &Path) -> crate::Result<bool> {
        let Some(keep) = self.plan.torn_cache_bytes else {
            return Ok(false);
        };
        let bytes = std::fs::read(path)
            .map_err(|e| crate::EhybError::Io(format!("{}: {e}", path.display())))?;
        let keep = (keep as usize).min(bytes.len().saturating_sub(1));
        std::fs::write(path, &bytes[..keep])
            .map_err(|e| crate::EhybError::Io(format!("{}: {e}", path.display())))?;
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_seed_is_deterministic() {
        assert_eq!(FaultPlan::from_seed(7), FaultPlan::from_seed(7));
        assert_ne!(FaultPlan::from_seed(7), FaultPlan::from_seed(8));
        let p = FaultPlan::from_seed(7);
        assert!(p.saturate_requests >= 2);
        assert!(p.panic_on_call.unwrap() >= 1);
    }

    #[test]
    fn json_round_trip() {
        let p = FaultPlan::from_seed(42);
        let back = FaultPlan::from_json(&Json::parse(&p.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back, p);
        // None fields survive as JSON null.
        let p = FaultPlan { panic_on_call: None, ..p };
        let back = FaultPlan::from_json(&Json::parse(&p.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back, p);
    }

    #[test]
    fn from_json_rejects_missing_fields() {
        let j = Json::parse(r#"{"seed": 1}"#).unwrap();
        assert!(matches!(FaultPlan::from_json(&j), Err(crate::EhybError::Parse(_))));
    }

    #[test]
    fn injector_counter_is_shared_across_clones() {
        let inj = FaultInjector::new(FaultPlan::from_seed(3));
        let other = inj.clone();
        assert_eq!(inj.next_call(), 1);
        assert_eq!(other.next_call(), 2);
        assert_eq!(inj.calls(), 2);
    }

    #[test]
    fn poison_hits_only_the_scheduled_call() {
        let plan = FaultPlan { nan_on_call: Some(2), ..FaultPlan::from_seed(5) };
        let inj = FaultInjector::new(plan);
        let mut x = vec![1.0f64; 16];
        assert_eq!(inj.poison(1, &mut x), None);
        assert!(x.iter().all(|v| v.is_finite()));
        let idx = inj.poison(2, &mut x).unwrap();
        assert!(x[idx].is_nan());
        assert_eq!(x.iter().filter(|v| v.is_nan()).count(), 1);
        // Reproducible index.
        let mut x2 = vec![1.0f64; 16];
        assert_eq!(inj.poison(2, &mut x2), Some(idx));
    }

    #[test]
    fn tear_file_truncates() {
        let dir = std::env::temp_dir().join(format!("ehyb-tear-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("entry.json");
        std::fs::write(&path, "0123456789abcdef").unwrap();
        let plan = FaultPlan { torn_cache_bytes: Some(4), ..FaultPlan::from_seed(1) };
        assert!(FaultInjector::new(plan).tear_file(&path).unwrap());
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "0123");
        let no_tear = FaultPlan { torn_cache_bytes: None, ..FaultPlan::from_seed(1) };
        assert!(!FaultInjector::new(no_tear).tear_file(&path).unwrap());
        std::fs::remove_dir_all(&dir).ok();
    }
}
