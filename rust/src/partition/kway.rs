//! Multilevel k-way driver: coarsen → initial partition → project +
//! refine. Public entry point of the partitioning substrate.

use super::graph::Graph;
use super::initial::{bfs_band_partition, index_block_partition, random_partition};
use super::matching::coarsen;
use super::refine::{rebalance, refine};

/// Partitioning algorithm selector — the ablation axis of DESIGN.md §7.3.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PartitionMethod {
    /// Full multilevel (METIS-like): the default used by EHYB.
    Multilevel,
    /// Single-level BFS bands + refinement (cheaper, worse cut).
    BfsBand,
    /// Natural index blocks (no partitioner).
    IndexBlock,
    /// Random balanced assignment (worst case).
    Random,
}

#[derive(Clone, Debug)]
pub struct PartitionConfig {
    pub method: PartitionMethod,
    /// Refinement passes per level.
    pub refine_passes: usize,
    /// Coarsening stops at `max(k * coarsen_factor, 64)` vertices.
    pub coarsen_factor: usize,
    pub seed: u64,
}

impl Default for PartitionConfig {
    fn default() -> Self {
        Self {
            method: PartitionMethod::Multilevel,
            refine_passes: 4,
            coarsen_factor: 8,
            seed: 0x9E3779B9,
        }
    }
}

#[derive(Clone, Debug)]
pub struct PartitionResult {
    /// `assignment[v] ∈ [0, k)`.
    pub assignment: Vec<u32>,
    pub k: usize,
    /// Weight of cut edges (each counted once).
    pub edgecut: u64,
    /// Cut edges / total edges — predicts EHYB's ER fraction.
    pub cut_fraction: f64,
    /// Per-part loads.
    pub loads: Vec<u64>,
}

/// Partition `g` into `k` parts of weight ≤ `cap`.
///
/// Panics if `k * cap < total_vwgt` (infeasible).
pub fn partition_graph(g: &Graph, k: usize, cap: u64, cfg: &PartitionConfig) -> PartitionResult {
    assert!(k >= 1);
    assert!(
        k as u64 * cap >= g.total_vwgt(),
        "infeasible partition request: k={k} cap={cap} total={}",
        g.total_vwgt()
    );
    let assignment = match cfg.method {
        PartitionMethod::Random => {
            let mut part = random_partition(g, k, cap, cfg.seed);
            // Even the "random" baseline deserves capacity-safe output;
            // no refinement so it stays a true worst case.
            debug_assert!(g.part_loads(&part, k).iter().all(|&l| l <= cap));
            part.shrink_to_fit();
            part
        }
        PartitionMethod::IndexBlock => index_block_partition(g, k, cap),
        PartitionMethod::BfsBand => {
            let mut part = bfs_band_partition(g, k, cap);
            refine(g, &mut part, k, cap, cfg.refine_passes);
            part
        }
        PartitionMethod::Multilevel => multilevel(g, k, cap, cfg),
    };
    let edgecut = g.edgecut(&assignment);
    let nedges = g.nedges().max(1);
    PartitionResult {
        k,
        edgecut,
        cut_fraction: edgecut as f64 / nedges as f64,
        loads: g.part_loads(&assignment, k),
        assignment,
    }
}

fn multilevel(g: &Graph, k: usize, cap: u64, cfg: &PartitionConfig) -> Vec<u32> {
    // Cap coarse-vertex weight so the initial partition can still pack
    // parts under `cap` (each coarse vertex must fit with room to spare).
    let max_vwgt = ((cap / 4).max(1) as u32).min(u32::MAX);
    let target = (k * cfg.coarsen_factor).max(64);
    let levels = coarsen(g, target, max_vwgt, cfg.seed);

    // Partition the coarsest graph (may softly exceed `cap` due to
    // weighted-vertex fragmentation; repaired on the way down).
    let coarsest: &Graph = levels.last().map(|l| &l.graph).unwrap_or(g);
    let mut part = bfs_band_partition(coarsest, k, cap);
    rebalance(coarsest, &mut part, k, cap);
    refine(coarsest, &mut part, k, cap, cfg.refine_passes * 2);

    // Uncoarsen: project through each level, rebalancing + refining.
    for i in (0..levels.len()).rev() {
        let fine_graph: &Graph = if i == 0 { g } else { &levels[i - 1].graph };
        let cmap = &levels[i].cmap;
        let mut fine_part = vec![0u32; fine_graph.nvtx()];
        for v in 0..fine_graph.nvtx() {
            fine_part[v] = part[cmap[v] as usize];
        }
        rebalance(fine_graph, &mut fine_part, k, cap);
        refine(fine_graph, &mut fine_part, k, cap, cfg.refine_passes);
        part = fine_part;
    }
    // Unit weights at the finest level guarantee this final repair
    // succeeds, making the capacity invariant hard.
    rebalance(g, &mut part, k, cap);
    part
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::{poisson2d, poisson3d, unstructured_mesh};

    fn check(g: &Graph, r: &PartitionResult, k: usize, cap: u64) {
        assert_eq!(r.assignment.len(), g.nvtx());
        assert!(r.assignment.iter().all(|&p| (p as usize) < k));
        for (p, &load) in r.loads.iter().enumerate() {
            assert!(load <= cap, "part {p} load {load} > cap {cap}");
        }
        assert_eq!(r.loads.iter().sum::<u64>(), g.total_vwgt());
    }

    #[test]
    fn multilevel_on_grid() {
        let g = Graph::from_matrix_structure(&poisson2d::<f64>(32, 32));
        let (k, cap) = (16usize, 64u64);
        let r = partition_graph(&g, k, cap, &PartitionConfig::default());
        check(&g, &r, k, cap);
        // A 32x32 grid split into 16 parts of 64: ideal cut ~ 16 * 2 * 8.
        // Accept anything under 3x ideal.
        assert!(r.edgecut < 800, "edgecut={}", r.edgecut);
    }

    #[test]
    fn multilevel_beats_random_and_index_on_shuffled_mesh() {
        // The unstructured generator hides locality behind random labels:
        // index blocks are as bad as random; multilevel must recover it.
        let m = unstructured_mesh::<f64>(32, 32, 0.3, 7);
        let g = Graph::from_matrix_structure(&m);
        let (k, cap) = (16usize, 64u64);
        let mk = |method| {
            partition_graph(&g, k, cap, &PartitionConfig { method, ..Default::default() })
                .edgecut
        };
        let ml = mk(PartitionMethod::Multilevel);
        let ib = mk(PartitionMethod::IndexBlock);
        let rd = mk(PartitionMethod::Random);
        assert!(ml * 2 < ib, "multilevel={ml} index={ib}");
        assert!(ml * 2 < rd, "multilevel={ml} random={rd}");
    }

    #[test]
    fn all_methods_respect_capacity() {
        let g = Graph::from_matrix_structure(&poisson3d::<f64>(8, 8, 8));
        let (k, cap) = (8usize, 64u64);
        for method in [
            PartitionMethod::Multilevel,
            PartitionMethod::BfsBand,
            PartitionMethod::IndexBlock,
            PartitionMethod::Random,
        ] {
            let r = partition_graph(&g, k, cap, &PartitionConfig { method, ..Default::default() });
            check(&g, &r, k, cap);
        }
    }

    #[test]
    fn k_equals_one() {
        let g = Graph::from_matrix_structure(&poisson2d::<f64>(8, 8));
        let r = partition_graph(&g, 1, 64, &PartitionConfig::default());
        assert_eq!(r.edgecut, 0);
        assert!(r.assignment.iter().all(|&p| p == 0));
    }

    #[test]
    fn cut_fraction_in_unit_interval() {
        let g = Graph::from_matrix_structure(&poisson2d::<f64>(16, 16));
        let r = partition_graph(&g, 8, 32, &PartitionConfig::default());
        assert!((0.0..=1.0).contains(&r.cut_fraction));
    }

    #[test]
    fn deterministic_given_seed() {
        let g = Graph::from_matrix_structure(&poisson2d::<f64>(16, 16));
        let cfg = PartitionConfig::default();
        let a = partition_graph(&g, 8, 32, &cfg);
        let b = partition_graph(&g, 8, 32, &cfg);
        assert_eq!(a.assignment, b.assignment);
    }
}
