//! Greedy k-way boundary refinement (FM-style, no rollback): repeatedly
//! move boundary vertices to the neighbouring part with the largest
//! edge-cut gain, subject to the hard per-part capacity. A few passes per
//! uncoarsening level, matching METIS's refinement budget.

use super::graph::Graph;

/// One refinement pass; returns the total gain achieved.
/// `loads` is updated in place.
pub fn refine_pass(g: &Graph, part: &mut [u32], loads: &mut [u64], cap: u64) -> i64 {
    let n = g.nvtx();
    let k = loads.len();
    let mut total_gain = 0i64;
    // Connectivity scratch: weight of v's edges into each part.
    let mut conn = vec![0i64; k];
    let mut touched: Vec<u32> = Vec::new();
    for v in 0..n {
        let pv = part[v] as usize;
        // Compute connectivity to each adjacent part.
        let mut is_boundary = false;
        for (u, w) in g.neighbors(v) {
            let pu = part[u] as usize;
            if conn[pu] == 0 {
                touched.push(pu as u32);
            }
            conn[pu] += w as i64;
            if pu != pv {
                is_boundary = true;
            }
        }
        if is_boundary {
            let internal = conn[pv];
            let mut best: Option<(usize, i64)> = None;
            for &t in &touched {
                let t = t as usize;
                if t == pv {
                    continue;
                }
                let gain = conn[t] - internal;
                if gain > 0
                    && loads[t] + g.vwgt[v] as u64 <= cap
                    && best.map(|(_, bg)| gain > bg).unwrap_or(true)
                {
                    best = Some((t, gain));
                }
            }
            if let Some((t, gain)) = best {
                loads[pv] -= g.vwgt[v] as u64;
                loads[t] += g.vwgt[v] as u64;
                part[v] = t as u32;
                total_gain += gain;
            }
        }
        for &t in &touched {
            conn[t as usize] = 0;
        }
        touched.clear();
    }
    total_gain
}

/// Run up to `max_passes` refinement passes, stopping early when a pass
/// yields no gain.
pub fn refine(g: &Graph, part: &mut [u32], k: usize, cap: u64, max_passes: usize) -> i64 {
    let mut loads = g.part_loads(part, k);
    let mut total = 0i64;
    for _ in 0..max_passes {
        let gain = refine_pass(g, part, &mut loads, cap);
        total += gain;
        if gain == 0 {
            break;
        }
    }
    total
}

/// Repair capacity violations: move vertices out of overfull parts into
/// parts with room, choosing moves that hurt the cut least (lowest
/// internal connectivity, highest connectivity to a receiving part).
/// With unit vertex weights and `k*cap ≥ n` this always terminates with
/// every part ≤ cap.
pub fn rebalance(g: &Graph, part: &mut [u32], k: usize, cap: u64) {
    let mut loads = g.part_loads(part, k);
    loop {
        let Some(src) = (0..k).find(|&p| loads[p] > cap) else { return };
        // Candidates in src, cheapest-to-move first.
        let mut cands: Vec<(i64, u32, usize)> = Vec::new(); // (internal-external, vwgt, v)
        for v in 0..g.nvtx() {
            if part[v] as usize != src {
                continue;
            }
            let mut internal = 0i64;
            let mut best_ext = 0i64;
            for (u, w) in g.neighbors(v) {
                if part[u] as usize == src {
                    internal += w as i64;
                } else {
                    best_ext = best_ext.max(w as i64);
                }
            }
            cands.push((internal - best_ext, g.vwgt[v], v));
        }
        cands.sort();
        let mut moved = false;
        for &(_, w, v) in &cands {
            if loads[src] <= cap {
                break;
            }
            // Prefer the connected non-full part with the most room gain;
            // fall back to the globally least-loaded part with room.
            let mut target: Option<usize> = None;
            let mut best_conn = -1i64;
            for (u, ew) in g.neighbors(v) {
                let p = part[u] as usize;
                if p != src && loads[p] + w as u64 <= cap && (ew as i64) > best_conn {
                    best_conn = ew as i64;
                    target = Some(p);
                }
            }
            if target.is_none() {
                target = (0..k)
                    .filter(|&p| p != src && loads[p] + w as u64 <= cap)
                    .min_by_key(|&p| loads[p]);
            }
            if let Some(t) = target {
                loads[src] -= w as u64;
                loads[t] += w as u64;
                part[v] = t as u32;
                moved = true;
            }
        }
        if !moved {
            // No single vertex fits anywhere (heavy coarse vertices):
            // give up — the caller rebalances again at a finer level
            // where weights shrink.
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::initial::random_partition;
    use crate::sparse::gen::poisson2d;

    #[test]
    fn rebalance_fixes_overfull_parts() {
        let g = Graph::from_matrix_structure(&poisson2d::<f64>(8, 8));
        // Everything crammed into part 0.
        let mut part = vec![0u32; 64];
        rebalance(&g, &mut part, 4, 16);
        let loads = g.part_loads(&part, 4);
        assert!(loads.iter().all(|&l| l <= 16), "{loads:?}");
    }

    #[test]
    fn refinement_never_worsens_cut() {
        let g = Graph::from_matrix_structure(&poisson2d::<f64>(16, 16));
        let (k, cap) = (8usize, 36u64);
        let mut part = random_partition(&g, k, cap, 11);
        let before = g.edgecut(&part);
        refine(&g, &mut part, k, cap, 8);
        let after = g.edgecut(&part);
        assert!(after <= before, "cut got worse: {before} -> {after}");
        // Random partitions of a grid have lots of slack; expect real gains.
        assert!(after < before, "no improvement at all is suspicious");
    }

    #[test]
    fn refinement_respects_capacity() {
        let g = Graph::from_matrix_structure(&poisson2d::<f64>(12, 12));
        let (k, cap) = (6usize, 26u64);
        let mut part = random_partition(&g, k, cap, 5);
        refine(&g, &mut part, k, cap, 8);
        for (p, &load) in g.part_loads(&part, k).iter().enumerate() {
            assert!(load <= cap, "part {p}: {load} > {cap}");
        }
    }

    #[test]
    fn gain_reported_matches_cut_delta() {
        let g = Graph::from_matrix_structure(&poisson2d::<f64>(10, 10));
        let (k, cap) = (4usize, 30u64);
        let mut part = random_partition(&g, k, cap, 9);
        let before = g.edgecut(&part) as i64;
        let gain = refine(&g, &mut part, k, cap, 16);
        let after = g.edgecut(&part) as i64;
        assert_eq!(before - after, gain);
    }

    #[test]
    fn already_optimal_is_stable() {
        let g = Graph::from_matrix_structure(&poisson2d::<f64>(8, 8));
        // Perfect halves of the grid (columns 0-3 vs 4-7).
        let mut part: Vec<u32> = (0..64).map(|v| if v % 8 < 4 { 0 } else { 1 }).collect();
        let before = g.edgecut(&part);
        refine(&g, &mut part, 2, 40, 4);
        assert!(g.edgecut(&part) <= before);
    }
}
