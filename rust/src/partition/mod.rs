//! Multilevel k-way graph partitioning — the METIS substitute
//! (DESIGN.md §4). Paper Algorithm 1 line 2 calls ParMETIS on the
//! sparsity graph; here the same role is filled by a classical
//! multilevel scheme:
//!
//! 1. **Coarsening** ([`matching`]): heavy-edge matching + contraction
//!    until the graph is small.
//! 2. **Initial partitioning** ([`initial`]): BFS-band growth from a
//!    pseudo-peripheral seed, chunked into k capacity-bounded parts.
//! 3. **Uncoarsening + refinement** ([`refine`]): project the partition
//!    back level by level, improving it with greedy boundary FM moves
//!    under a hard per-part capacity (EHYB needs every partition to fit
//!    its x-slice cache: |part| ≤ VecSize).
//!
//! The quality metric that matters downstream is the **edge-cut
//! fraction**: every cut edge becomes an ER entry (uncached vector
//! access), so `PartitionResult::edgecut / total_edges` ≈ EHYB's
//! `er_fraction`.

pub mod graph;
pub mod matching;
pub mod initial;
pub mod refine;
pub mod kway;

pub use graph::Graph;
pub use kway::{partition_graph, PartitionConfig, PartitionMethod, PartitionResult};
