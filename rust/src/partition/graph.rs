//! Undirected weighted graph in CSR (METIS xadj/adjncy) layout, built
//! from a sparse matrix's symmetrized structure (paper §3.1: "the sparse
//! matrix will be recognized as an undirected graph with each row/column
//! as a vertex and each entry as an edge").

use crate::sparse::csr::Csr;
use crate::sparse::scalar::Scalar;

#[derive(Clone, Debug)]
pub struct Graph {
    /// Adjacency offsets, `len = nvtx + 1`.
    pub xadj: Vec<u32>,
    /// Neighbour lists (no self-loops).
    pub adjncy: Vec<u32>,
    /// Vertex weights (1 at the finest level; sums under contraction).
    pub vwgt: Vec<u32>,
    /// Edge weights (1 at the finest level; parallel edges merge).
    pub adjwgt: Vec<u32>,
}

impl Graph {
    pub fn nvtx(&self) -> usize {
        self.xadj.len() - 1
    }

    pub fn nedges(&self) -> usize {
        self.adjncy.len() / 2
    }

    pub fn total_vwgt(&self) -> u64 {
        self.vwgt.iter().map(|&w| w as u64).sum()
    }

    #[inline]
    pub fn neighbors(&self, v: usize) -> impl Iterator<Item = (usize, u32)> + '_ {
        let lo = self.xadj[v] as usize;
        let hi = self.xadj[v + 1] as usize;
        self.adjncy[lo..hi].iter().zip(&self.adjwgt[lo..hi]).map(|(&u, &w)| (u as usize, w))
    }

    #[inline]
    pub fn degree(&self, v: usize) -> usize {
        (self.xadj[v + 1] - self.xadj[v]) as usize
    }

    /// Build from a square matrix's structure: symmetrize, drop the
    /// diagonal, unit vertex/edge weights.
    pub fn from_matrix_structure<S: Scalar>(m: &Csr<S>) -> Graph {
        assert_eq!(m.nrows(), m.ncols(), "partitioning graph needs a square matrix");
        let s = m.symmetrize_structure();
        let n = s.nrows();
        let mut xadj = vec![0u32; n + 1];
        for i in 0..n {
            let (cols, _) = s.row(i);
            let deg = cols.iter().filter(|&&c| c as usize != i).count();
            xadj[i + 1] = xadj[i] + deg as u32;
        }
        let mut adjncy = vec![0u32; xadj[n] as usize];
        let mut pos = xadj.clone();
        for i in 0..n {
            let (cols, _) = s.row(i);
            for &c in cols {
                if c as usize != i {
                    adjncy[pos[i] as usize] = c;
                    pos[i] += 1;
                }
            }
        }
        let nadj = adjncy.len();
        Graph { xadj, adjncy, vwgt: vec![1; n], adjwgt: vec![1; nadj] }
    }

    /// Total weight of edges crossing partitions (each edge counted once).
    pub fn edgecut(&self, part: &[u32]) -> u64 {
        let mut cut = 0u64;
        for v in 0..self.nvtx() {
            for (u, w) in self.neighbors(v) {
                if part[v] != part[u] {
                    cut += w as u64;
                }
            }
        }
        cut / 2
    }

    /// Per-part vertex-weight loads.
    pub fn part_loads(&self, part: &[u32], k: usize) -> Vec<u64> {
        let mut loads = vec![0u64; k];
        for v in 0..self.nvtx() {
            loads[part[v] as usize] += self.vwgt[v] as u64;
        }
        loads
    }

    /// A pseudo-peripheral vertex: BFS twice from an arbitrary start —
    /// standard device to make BFS-band partitions long and thin.
    pub fn pseudo_peripheral(&self, start: usize) -> usize {
        let mut far = start;
        for _ in 0..2 {
            let order = self.bfs_order(far);
            if let Some(&last) = order.last() {
                far = last as usize;
            }
        }
        far
    }

    /// BFS visitation order from `start`, visiting every component
    /// (disconnected graphs restart from the lowest unvisited vertex).
    pub fn bfs_order(&self, start: usize) -> Vec<u32> {
        let n = self.nvtx();
        let mut order = Vec::with_capacity(n);
        let mut seen = vec![false; n];
        let mut queue = std::collections::VecDeque::new();
        let mut next_unseen = 0usize;
        let mut s = start.min(n.saturating_sub(1));
        while order.len() < n {
            if !seen[s] {
                seen[s] = true;
                queue.push_back(s);
            }
            while let Some(v) = queue.pop_front() {
                order.push(v as u32);
                for (u, _) in self.neighbors(v) {
                    if !seen[u] {
                        seen[u] = true;
                        queue.push_back(u);
                    }
                }
            }
            // Next component.
            while next_unseen < n && seen[next_unseen] {
                next_unseen += 1;
            }
            if next_unseen >= n {
                break;
            }
            s = next_unseen;
        }
        order
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::{poisson1d, poisson2d};

    #[test]
    fn from_poisson1d() {
        let g = Graph::from_matrix_structure(&poisson1d::<f64>(5));
        assert_eq!(g.nvtx(), 5);
        assert_eq!(g.nedges(), 4);
        assert_eq!(g.degree(0), 1);
        assert_eq!(g.degree(2), 2);
    }

    #[test]
    fn no_self_loops() {
        let g = Graph::from_matrix_structure(&poisson2d::<f64>(6, 6));
        for v in 0..g.nvtx() {
            assert!(g.neighbors(v).all(|(u, _)| u != v));
        }
    }

    #[test]
    fn edgecut_counts_each_edge_once() {
        let g = Graph::from_matrix_structure(&poisson1d::<f64>(4));
        // Parts {0,1} {2,3}: only edge (1,2) crosses.
        assert_eq!(g.edgecut(&[0, 0, 1, 1]), 1);
        assert_eq!(g.edgecut(&[0, 0, 0, 0]), 0);
        assert_eq!(g.edgecut(&[0, 1, 0, 1]), 3);
    }

    #[test]
    fn bfs_order_visits_all() {
        let g = Graph::from_matrix_structure(&poisson2d::<f64>(5, 5));
        let order = g.bfs_order(0);
        assert_eq!(order.len(), 25);
        let mut s = order.clone();
        s.sort_unstable();
        assert_eq!(s, (0..25).collect::<Vec<u32>>());
    }

    #[test]
    fn bfs_handles_disconnected() {
        use crate::sparse::coo::Coo;
        // Two disconnected dumbbells.
        let m = Coo::<f64>::from_triplets(
            4,
            4,
            vec![(0, 1, 1.0), (1, 0, 1.0), (2, 3, 1.0), (3, 2, 1.0)],
        )
        .unwrap()
        .to_csr();
        let g = Graph::from_matrix_structure(&m);
        assert_eq!(g.bfs_order(0).len(), 4);
    }

    #[test]
    fn pseudo_peripheral_on_path() {
        let g = Graph::from_matrix_structure(&poisson1d::<f64>(10));
        let p = g.pseudo_peripheral(5);
        assert!(p == 0 || p == 9, "expected an end of the path, got {p}");
    }

    #[test]
    fn part_loads_sum_to_total() {
        let g = Graph::from_matrix_structure(&poisson2d::<f64>(4, 4));
        let part: Vec<u32> = (0..16).map(|v| (v % 3) as u32).collect();
        let loads = g.part_loads(&part, 3);
        assert_eq!(loads.iter().sum::<u64>(), g.total_vwgt());
    }
}
