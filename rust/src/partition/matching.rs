//! Coarsening: heavy-edge matching (HEM) + graph contraction, the
//! standard METIS coarsening step. Matched vertex pairs merge into one
//! coarse vertex; parallel edges merge with summed weights, so the
//! edge-cut of a coarse partition equals the edge-cut of its projection —
//! the invariant multilevel partitioning rests on.

use super::graph::Graph;
use crate::util::Xoshiro256;

pub const UNMATCHED: u32 = u32::MAX;

/// One coarsening level: the coarse graph plus the fine→coarse map.
pub struct CoarseLevel {
    pub graph: Graph,
    /// `cmap[fine_vertex] = coarse_vertex`.
    pub cmap: Vec<u32>,
}

/// Heavy-edge matching. Visits vertices in random order; each unmatched
/// vertex matches its unmatched neighbour with the heaviest connecting
/// edge, subject to the merged weight staying ≤ `max_vwgt` (keeps coarse
/// vertices small enough for the capacity-bounded initial partitioning).
pub fn heavy_edge_matching(g: &Graph, max_vwgt: u32, rng: &mut Xoshiro256) -> Vec<u32> {
    let n = g.nvtx();
    let mut matched = vec![UNMATCHED; n];
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    for &v0 in &order {
        let v = v0 as usize;
        if matched[v] != UNMATCHED {
            continue;
        }
        let mut best: Option<(usize, u32)> = None;
        for (u, w) in g.neighbors(v) {
            if matched[u] == UNMATCHED
                && u != v
                && g.vwgt[v].saturating_add(g.vwgt[u]) <= max_vwgt
                && best.map(|(_, bw)| w > bw).unwrap_or(true)
            {
                best = Some((u, w));
            }
        }
        match best {
            Some((u, _)) => {
                matched[v] = u as u32;
                matched[u] = v as u32;
            }
            None => matched[v] = v as u32, // self-matched (stays single)
        }
    }
    matched
}

/// Contract a matching into the coarse graph.
pub fn contract(g: &Graph, matched: &[u32]) -> CoarseLevel {
    let n = g.nvtx();
    let mut cmap = vec![UNMATCHED; n];
    let mut ncoarse = 0u32;
    for v in 0..n {
        if cmap[v] != UNMATCHED {
            continue;
        }
        let m = matched[v] as usize;
        cmap[v] = ncoarse;
        cmap[m] = ncoarse; // m == v for self-matched
        ncoarse += 1;
    }
    let nc = ncoarse as usize;

    let mut vwgt = vec![0u32; nc];
    for v in 0..n {
        vwgt[cmap[v] as usize] += g.vwgt[v];
        if matched[v] as usize != v {
            // counted once: skip the partner when v > partner
        }
    }
    // The loop above double-counts pairs: each fine vertex adds its own
    // weight exactly once, so actually it's correct — cmap maps both
    // endpoints to the same coarse vertex and each fine vertex iterates
    // once. (Left as a comment because it reads like a bug.)

    // Merge adjacency with a scatter array.
    let mut xadj = vec![0u32; nc + 1];
    let mut adjncy: Vec<u32> = Vec::with_capacity(g.adjncy.len());
    let mut adjwgt: Vec<u32> = Vec::with_capacity(g.adjncy.len());
    let mut slot_of: Vec<u32> = vec![UNMATCHED; nc]; // coarse neighbour -> index in current row
    let mut touched: Vec<u32> = Vec::new();

    // Build rows in coarse-vertex order: for that we need the fine
    // vertices of each coarse vertex.
    let mut members: Vec<Vec<u32>> = vec![Vec::with_capacity(2); nc];
    for v in 0..n {
        members[cmap[v] as usize].push(v as u32);
    }

    for c in 0..nc {
        let row_start = adjncy.len();
        for &vf in &members[c] {
            for (u, w) in g.neighbors(vf as usize) {
                let cu = cmap[u] as usize;
                if cu == c {
                    continue; // internal edge disappears
                }
                if slot_of[cu] == UNMATCHED {
                    slot_of[cu] = adjncy.len() as u32;
                    adjncy.push(cu as u32);
                    adjwgt.push(w);
                    touched.push(cu as u32);
                } else {
                    adjwgt[slot_of[cu] as usize] += w;
                }
            }
        }
        for &t in &touched {
            slot_of[t as usize] = UNMATCHED;
        }
        touched.clear();
        xadj[c + 1] = xadj[c] + (adjncy.len() - row_start) as u32;
    }

    CoarseLevel { graph: Graph { xadj, adjncy, vwgt, adjwgt }, cmap }
}

/// Coarsen until ≤ `target_nvtx` vertices or progress stalls.
/// Returns levels finest-first (level 0 map refers to the input graph).
pub fn coarsen(g: &Graph, target_nvtx: usize, max_vwgt: u32, seed: u64) -> Vec<CoarseLevel> {
    let mut levels: Vec<CoarseLevel> = Vec::new();
    let mut rng = Xoshiro256::new(seed);
    let mut current = g.clone();
    while current.nvtx() > target_nvtx {
        let matched = heavy_edge_matching(&current, max_vwgt, &mut rng);
        let level = contract(&current, &matched);
        // Stalled (e.g. matching found nothing due to weight caps).
        if level.graph.nvtx() as f64 > current.nvtx() as f64 * 0.95 {
            break;
        }
        current = level.graph.clone();
        levels.push(level);
    }
    levels
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::{poisson1d, poisson2d};
    use crate::util::Xoshiro256;

    #[test]
    fn matching_is_symmetric_and_weight_capped() {
        let g = Graph::from_matrix_structure(&poisson2d::<f64>(10, 10));
        let mut rng = Xoshiro256::new(1);
        let m = heavy_edge_matching(&g, 2, &mut rng);
        for v in 0..g.nvtx() {
            let u = m[v] as usize;
            assert_ne!(m[v], UNMATCHED);
            assert_eq!(m[u] as usize, v, "matching not symmetric at {v}");
        }
    }

    #[test]
    fn contract_preserves_total_vwgt() {
        let g = Graph::from_matrix_structure(&poisson2d::<f64>(8, 8));
        let mut rng = Xoshiro256::new(2);
        let m = heavy_edge_matching(&g, 4, &mut rng);
        let lvl = contract(&g, &m);
        assert_eq!(lvl.graph.total_vwgt(), g.total_vwgt());
        assert!(lvl.graph.nvtx() < g.nvtx());
    }

    #[test]
    fn contract_no_self_loops() {
        let g = Graph::from_matrix_structure(&poisson2d::<f64>(6, 6));
        let mut rng = Xoshiro256::new(3);
        let m = heavy_edge_matching(&g, 8, &mut rng);
        let lvl = contract(&g, &m);
        for v in 0..lvl.graph.nvtx() {
            assert!(lvl.graph.neighbors(v).all(|(u, _)| u != v));
        }
    }

    #[test]
    fn cut_invariant_under_projection() {
        // Partition the coarse graph, project to fine: cuts must agree.
        let g = Graph::from_matrix_structure(&poisson2d::<f64>(8, 8));
        let mut rng = Xoshiro256::new(4);
        let m = heavy_edge_matching(&g, 4, &mut rng);
        let lvl = contract(&g, &m);
        let coarse_part: Vec<u32> = (0..lvl.graph.nvtx()).map(|v| (v % 2) as u32).collect();
        let fine_part: Vec<u32> =
            (0..g.nvtx()).map(|v| coarse_part[lvl.cmap[v] as usize]).collect();
        assert_eq!(lvl.graph.edgecut(&coarse_part), g.edgecut(&fine_part));
    }

    #[test]
    fn coarsen_reaches_target() {
        let g = Graph::from_matrix_structure(&poisson2d::<f64>(20, 20));
        let levels = coarsen(&g, 50, u32::MAX, 7);
        assert!(!levels.is_empty());
        assert!(levels.last().unwrap().graph.nvtx() <= 400); // shrank
    }

    #[test]
    fn coarsen_path_graph() {
        let g = Graph::from_matrix_structure(&poisson1d::<f64>(64));
        let levels = coarsen(&g, 8, u32::MAX, 5);
        let last = levels.last().unwrap();
        assert!(last.graph.nvtx() < 64);
        assert_eq!(last.graph.total_vwgt(), 64);
    }
}
