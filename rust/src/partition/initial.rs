//! Initial k-way partitioning of the coarsest graph: BFS-band growth.
//! Vertices are visited in BFS order from a pseudo-peripheral seed and
//! packed greedily into parts under a hard capacity. For mesh-like
//! graphs this yields contiguous "bands" whose boundaries the FM
//! refinement then polishes.

use super::graph::Graph;

/// Capacity-bounded BFS-band partition into `k` parts. `cap` is the
/// maximum vertex weight per part; must satisfy `k * cap ≥ total_vwgt`.
/// Returns `part[v] ∈ [0, k)`.
pub fn bfs_band_partition(g: &Graph, k: usize, cap: u64) -> Vec<u32> {
    let n = g.nvtx();
    assert!(k >= 1);
    assert!(
        k as u64 * cap >= g.total_vwgt(),
        "infeasible: k={k} cap={cap} total={}",
        g.total_vwgt()
    );
    let seed = if n > 0 { g.pseudo_peripheral(0) } else { 0 };
    let order = g.bfs_order(seed);
    pack_in_order(g, &order, k, cap)
}

/// Pack vertices in the given visit order into k parts of capacity
/// `cap`: fill the current part while it fits, advance otherwise; when
/// fragmentation leaves no part with room (possible with weighted coarse
/// vertices and zero slack), spill to the least-loaded part. Unit-weight
/// graphs (the finest level) never spill; weighted coarse-level spills
/// are repaired by [`super::refine::rebalance`] after projection.
fn pack_in_order(g: &Graph, order: &[u32], k: usize, cap: u64) -> Vec<u32> {
    let mut part = vec![0u32; g.nvtx()];
    let mut loads = vec![0u64; k];
    let mut cur = 0usize;
    for &v0 in order {
        let v = v0 as usize;
        let w = g.vwgt[v] as u64;
        if loads[cur] + w > cap {
            if cur + 1 < k {
                cur += 1;
            }
            if loads[cur] + w > cap {
                // Fragmented: first-fit anywhere with room, else spill to
                // the least-loaded part.
                cur = (0..k).find(|&p| loads[p] + w <= cap).unwrap_or_else(|| {
                    (0..k).min_by_key(|&p| loads[p]).unwrap()
                });
            }
        }
        part[v] = cur as u32;
        loads[cur] += w;
    }
    part
}

/// Round-robin partition by vertex index — the "no partitioner" ablation
/// baseline (what you get if you chunk rows naively).
pub fn index_block_partition(g: &Graph, k: usize, cap: u64) -> Vec<u32> {
    assert!(
        k as u64 * cap >= g.total_vwgt(),
        "infeasible: k={k} cap={cap} total={}",
        g.total_vwgt()
    );
    let order: Vec<u32> = (0..g.nvtx() as u32).collect();
    pack_in_order(g, &order, k, cap)
}

/// Random balanced partition — the worst-case ablation baseline.
pub fn random_partition(g: &Graph, k: usize, cap: u64, seed: u64) -> Vec<u32> {
    let n = g.nvtx();
    let mut order: Vec<u32> = (0..n as u32).collect();
    let mut rng = crate::util::Xoshiro256::new(seed);
    rng.shuffle(&mut order);
    let mut part = vec![0u32; n];
    let mut loads = vec![0u64; k];
    let mut cur = 0usize;
    for &v0 in &order {
        let v = v0 as usize;
        let w = g.vwgt[v] as u64;
        let mut tries = 0;
        while loads[cur] + w > cap && tries < k {
            cur = (cur + 1) % k;
            tries += 1;
        }
        part[v] = cur as u32;
        loads[cur] += w;
        cur = (cur + 1) % k;
    }
    part
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::poisson2d;

    fn grid_graph() -> Graph {
        Graph::from_matrix_structure(&poisson2d::<f64>(16, 16))
    }

    fn check_capacity(g: &Graph, part: &[u32], k: usize, cap: u64) {
        for (p, &load) in g.part_loads(part, k).iter().enumerate() {
            assert!(load <= cap, "part {p} load {load} > cap {cap}");
        }
    }

    #[test]
    fn bfs_band_respects_capacity() {
        let g = grid_graph();
        let (k, cap) = (8, 32u64);
        let part = bfs_band_partition(&g, k, cap);
        check_capacity(&g, &part, k, cap);
    }

    #[test]
    fn bfs_band_better_than_random() {
        let g = grid_graph();
        let (k, cap) = (8, 32u64);
        let bfs = bfs_band_partition(&g, k, cap);
        let rnd = random_partition(&g, k, cap, 1);
        assert!(
            g.edgecut(&bfs) < g.edgecut(&rnd),
            "bfs={} random={}",
            g.edgecut(&bfs),
            g.edgecut(&rnd)
        );
    }

    #[test]
    fn index_block_respects_capacity() {
        let g = grid_graph();
        let part = index_block_partition(&g, 4, 64);
        check_capacity(&g, &part, 4, 64);
    }

    #[test]
    fn random_respects_capacity() {
        let g = grid_graph();
        let part = random_partition(&g, 4, 64, 3);
        check_capacity(&g, &part, 4, 64);
    }

    #[test]
    #[should_panic(expected = "infeasible")]
    fn infeasible_capacity_panics() {
        let g = grid_graph();
        bfs_band_partition(&g, 2, 10);
    }

    #[test]
    fn single_part() {
        let g = grid_graph();
        let part = bfs_band_partition(&g, 1, 256);
        assert!(part.iter().all(|&p| p == 0));
    }
}
