//! EHYB preprocessing — paper Algorithms 1 and 2.
//!
//! Pipeline (`EhybPlan::build`):
//!
//! 1. **Cache sizing** ([`cache_size`]): paper equations (1)–(2) pick the
//!    partition count `K × P` and the x-slice size `VecSize` from the
//!    matrix dimension, element width τ, processor count P, and the
//!    shared-memory (VMEM budget) cap.
//! 2. **Partitioning** (Algorithm 1 line 2): the matrix structure graph
//!    goes through [`crate::partition::partition_graph`] with hard
//!    capacity `VecSize`.
//! 3. **Counting + reordering** (Algorithm 1 lines 3–27): per row, count
//!    in-partition vs out-of-partition entries; within each partition
//!    sort rows by *descending* in-partition count (kills slice padding
//!    and warp divergence); ER rows sort globally by descending count;
//!    emit `ReorderTable` (perm), `yIdxER`, and the slice position
//!    vectors.
//! 4. **Reordering phase** (Algorithm 2): scatter values/columns into the
//!    sliced-ELL arrays (partition-local u16 columns) and the ER arrays.
//!
//! The two phases are timed separately — Figure 6 reports exactly this
//! decomposition (partitioning ≈ 400–1500× one SpMV, reordering 50–400×).

pub mod cache_size;
pub mod timing;

use crate::partition::{partition_graph, Graph, PartitionConfig, PartitionResult};
use crate::sparse::csr::Csr;
use crate::sparse::ehyb::EhybMatrix;
use crate::sparse::scalar::Scalar;
use crate::util::Timer;
pub use cache_size::{cache_plan, CachePlan, DeviceParams};
pub use timing::PreprocessTimings;

/// Tunables for the preprocessing pipeline.
#[derive(Clone, Debug)]
pub struct PreprocessConfig {
    /// Warp size on the target device; slice height of the ELL part.
    pub slice_height: usize,
    /// Device model used by equations (1)–(2).
    pub device: DeviceParams,
    /// Override VecSize directly (testing / ablations); must be a
    /// multiple of `slice_height`.
    pub vec_size_override: Option<usize>,
    /// Graph-partitioner settings.
    pub partition: PartitionConfig,
    /// Paper's descending-nnz in-partition sort (ablation §7.4 turns it
    /// off to measure slice-padding and divergence cost).
    pub sort_descending: bool,
    /// ELL/ER width cutoff: a row keeps at most this many in-partition
    /// entries in the sliced-ELL part; the excess spills into its ER
    /// row (alongside any out-of-partition entries). Caps the slice
    /// width a single heavy row can force on its 31 neighbours, trading
    /// ELL padding for ER traffic — a knob the `autotune` tuner
    /// searches. `None` (default) keeps the paper's membership-only
    /// split and is bit-identical to the pre-knob pipeline.
    pub ell_width_cutoff: Option<u32>,
}

impl Default for PreprocessConfig {
    fn default() -> Self {
        Self {
            slice_height: 32,
            device: DeviceParams::v100(),
            vec_size_override: None,
            partition: PartitionConfig::default(),
            sort_descending: true,
            ell_width_cutoff: None,
        }
    }
}

/// Output of preprocessing: the EHYB matrix plus provenance.
#[derive(Clone, Debug)]
pub struct EhybPlan<S: Scalar> {
    pub matrix: EhybMatrix<S>,
    pub partition: PartitionResult,
    pub cache: CachePlan,
    pub timings: PreprocessTimings,
}

impl<S: Scalar> EhybPlan<S> {
    /// Run the full preprocessing pipeline on a square CSR matrix.
    pub fn build(m: &Csr<S>, cfg: &PreprocessConfig) -> crate::Result<EhybPlan<S>> {
        if m.nrows() != m.ncols() {
            return Err(crate::EhybError::UnsupportedFormat(format!(
                "EHYB requires a square matrix, got {}x{}",
                m.nrows(),
                m.ncols()
            )));
        }
        if m.nrows() == 0 {
            return Err(crate::EhybError::UnsupportedFormat("empty matrix".into()));
        }
        let n = m.nrows();
        let h = cfg.slice_height;
        if let Some(c) = cfg.ell_width_cutoff {
            crate::ensure!(c >= 1, "ell_width_cutoff must be >= 1, got {c}");
        }

        // --- Equations (1)-(2): partition count and cache size. ---
        let cache = match cfg.vec_size_override {
            Some(v) => {
                crate::ensure!(v % h == 0 && v <= 1 << 16, "bad vec_size override {v}");
                CachePlan { vec_size: v, num_parts: n.div_ceil(v), k: 0 }
            }
            None => cache_plan::<S>(n, h, &cfg.device),
        };
        let vec_size = cache.vec_size;
        let num_parts = cache.num_parts;

        // --- Algorithm 1 line 2: graph partitioning (timed). ---
        let t = Timer::start();
        let graph = Graph::from_matrix_structure(m);
        let partition = partition_graph(&graph, num_parts, vec_size as u64, &cfg.partition);
        let partition_secs = t.elapsed_secs();
        // The assembler scatters by partition rank; an assignment that
        // misses rows or overfills a part would corrupt the layout, so
        // fail with a typed error instead.
        if partition.assignment.len() != n {
            return Err(crate::EhybError::PartitionFailed(format!(
                "assignment covers {} of {} rows",
                partition.assignment.len(),
                n
            )));
        }
        if let Some((p, &load)) =
            partition.loads.iter().enumerate().find(|(_, &l)| l > vec_size as u64)
        {
            return Err(crate::EhybError::PartitionFailed(format!(
                "part {p} load {load} exceeds capacity {vec_size}"
            )));
        }

        // --- Algorithm 1 lines 3-27 + Algorithm 2 (timed as "reorder"). ---
        let t = Timer::start();
        let matrix = assemble(
            m,
            &partition.assignment,
            num_parts,
            vec_size,
            h,
            cfg.sort_descending,
            cfg.ell_width_cutoff,
        );
        let reorder_secs = t.elapsed_secs();

        debug_assert!(matrix.validate().is_ok(), "{:?}", matrix.validate());
        Ok(EhybPlan {
            matrix,
            partition,
            cache,
            timings: PreprocessTimings { partition_secs, reorder_secs },
        })
    }
}

/// Algorithm 1 (counting, sorting, metadata) + Algorithm 2 (scatter).
/// `ell_width_cutoff` caps per-row ELL entries: a row's first `cutoff`
/// in-partition entries (in column order) stay in the sliced-ELL part,
/// the rest spill into its ER row.
fn assemble<S: Scalar>(
    m: &Csr<S>,
    assignment: &[u32],
    num_parts: usize,
    vec_size: usize,
    h: usize,
    sort_descending: bool,
    ell_width_cutoff: Option<u32>,
) -> EhybMatrix<S> {
    let n = m.nrows();
    let padded = num_parts * vec_size;

    // Members of each partition (original row ids).
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); num_parts];
    for v in 0..n {
        members[assignment[v] as usize].push(v as u32);
    }

    // Algorithm 1 lines 3-15: count in-partition (ELL) and
    // out-of-partition (ER) entries per row.
    let mut ell_len = vec![0u32; n];
    let mut er_len = vec![0u32; n];
    for row in 0..n {
        let (cols, _) = m.row(row);
        let pr = assignment[row];
        for &c in cols {
            if assignment[c as usize] == pr {
                ell_len[row] += 1;
            } else {
                er_len[row] += 1;
            }
        }
    }
    // ELL/ER width cutoff: spill each row's in-partition excess into its
    // ER row *before* sorting/width computation, so the layout below
    // sees the clamped lengths.
    if let Some(cut) = ell_width_cutoff {
        for row in 0..n {
            if ell_len[row] > cut {
                er_len[row] += ell_len[row] - cut;
                ell_len[row] = cut;
            }
        }
    }

    // Algorithm 1 lines 17-19: per-partition descending sort by ELL count
    // => ReorderTable (perm). Ties broken by original index for
    // determinism.
    let mut perm = vec![0u32; n];
    let mut iperm = vec![u32::MAX; padded];
    for (p, rows) in members.iter_mut().enumerate() {
        if sort_descending {
            rows.sort_by_key(|&r| (std::cmp::Reverse(ell_len[r as usize]), r));
        }
        for (rank, &r) in rows.iter().enumerate() {
            let new = p * vec_size + rank;
            perm[r as usize] = new as u32;
            iperm[new] = r;
        }
    }
    // Padding rows map to a sentinel beyond n; give them self-consistent
    // iperm values pointing past n (unpermute skips them).
    for (new, ip) in iperm.iter_mut().enumerate() {
        if *ip == u32::MAX {
            *ip = (n + new) as u32; // >= n => skipped by unpermute
        }
    }

    // Slice widths for the ELL part (paper WidthELL / PositionELL).
    let spp = vec_size / h;
    let num_slices = num_parts * spp;
    let mut slice_width = vec![0u32; num_slices];
    for (p, rows) in members.iter().enumerate() {
        for (rank, &r) in rows.iter().enumerate() {
            let s = p * spp + rank / h;
            slice_width[s] = slice_width[s].max(ell_len[r as usize]);
        }
    }
    let mut slice_ptr = vec![0u32; num_slices + 1];
    for s in 0..num_slices {
        slice_ptr[s + 1] = slice_ptr[s] + slice_width[s] * h as u32;
    }
    let ell_total = slice_ptr[num_slices] as usize;

    // Algorithm 1 line 16 + lines 23-26: ER rows sorted by descending ER
    // count (globally), yIdxER maps ER slot -> new row index.
    let mut er_rows_list: Vec<u32> = (0..n as u32).filter(|&r| er_len[r as usize] > 0).collect();
    er_rows_list.sort_by_key(|&r| (std::cmp::Reverse(er_len[r as usize]), r));
    let er_rows = er_rows_list.len();
    let y_idx_er: Vec<u32> = er_rows_list.iter().map(|&r| perm[r as usize]).collect();

    let er_slices = er_rows.div_ceil(h);
    let mut er_slice_width = vec![0u32; er_slices];
    for (j, &r) in er_rows_list.iter().enumerate() {
        let s = j / h;
        er_slice_width[s] = er_slice_width[s].max(er_len[r as usize]);
    }
    let mut er_slice_ptr = vec![0u32; er_slices + 1];
    for s in 0..er_slices {
        er_slice_ptr[s + 1] = er_slice_ptr[s] + er_slice_width[s] * h as u32;
    }
    let er_total = er_slice_ptr[er_slices] as usize;

    // --- Algorithm 2: scatter into the ELL and ER arrays. ---
    // Padding: col 0 / val 0 (gather-safe, numerically inert).
    let mut ell_cols = vec![0u16; ell_total];
    let mut ell_vals = vec![S::ZERO; ell_total];
    let mut er_cols = vec![0u32; er_total];
    let mut er_vals = vec![S::ZERO; er_total];

    // Position of each ER row in the ER layout.
    let mut er_rank = vec![u32::MAX; n];
    for (j, &r) in er_rows_list.iter().enumerate() {
        er_rank[r as usize] = j as u32;
    }

    let mut ell_nnz = 0usize;
    let mut er_nnz = 0usize;
    for row in 0..n {
        let (cols, vals) = m.row(row);
        let new_row = perm[row] as usize;
        let p = new_row / vec_size;
        let lane = new_row % h;
        let s = p * spp + (new_row % vec_size) / h;
        let ell_base = slice_ptr[s] as usize;
        let part_base = (p * vec_size) as u32;
        let mut k1 = 0usize; // Algorithm 2: k1 = in-partition entry counter
        let mut k2 = 0usize; // k2 = ER entry counter
        for (&c, &v) in cols.iter().zip(vals) {
            let nc = perm[c as usize];
            // In-partition entries beyond the clamped per-row ELL length
            // (the width cutoff) fall through to the ER branch.
            if assignment[c as usize] as usize == p && (k1 as u32) < ell_len[row] {
                let idx = ell_base + k1 * h + lane;
                ell_cols[idx] = (nc - part_base) as u16;
                ell_vals[idx] = v;
                k1 += 1;
                ell_nnz += 1;
            } else {
                let j = er_rank[row] as usize;
                let es = j / h;
                let elane = j % h;
                let idx = er_slice_ptr[es] as usize + k2 * h + elane;
                er_cols[idx] = nc;
                er_vals[idx] = v;
                k2 += 1;
                er_nnz += 1;
            }
        }
        debug_assert_eq!(k1 as u32, ell_len[row]);
        debug_assert_eq!(k2 as u32, er_len[row]);
    }

    EhybMatrix {
        n,
        num_parts,
        vec_size,
        slice_height: h,
        slice_ptr,
        slice_width,
        ell_cols,
        ell_vals,
        ell_nnz,
        er_slice_ptr,
        er_slice_width,
        er_rows,
        er_cols,
        er_vals,
        y_idx_er,
        er_nnz,
        perm,
        iperm,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::PartitionMethod;
    use crate::sparse::gen::{circuit, poisson2d, poisson3d, unstructured_mesh};
    use crate::util::check::assert_allclose;

    fn roundtrip<SM: Fn() -> Csr<f64>>(mk: SM, cfg: &PreprocessConfig) {
        let m = mk();
        let plan = EhybPlan::build(&m, cfg).unwrap();
        plan.matrix.validate().unwrap();
        let n = m.nrows();
        let x: Vec<f64> = (0..n).map(|i| ((i * 31 + 7) % 17) as f64 * 0.25 - 2.0).collect();
        let mut y_ref = vec![0.0; n];
        m.spmv(&x, &mut y_ref);
        let mut y = vec![0.0; n];
        plan.matrix.spmv(&x, &mut y);
        assert_allclose(&y, &y_ref, 1e-10, 1e-10).unwrap();
        // nnz conservation.
        assert_eq!(plan.matrix.nnz(), m.nnz());
    }

    fn small_cfg(vec_size: usize) -> PreprocessConfig {
        PreprocessConfig { vec_size_override: Some(vec_size), ..Default::default() }
    }

    #[test]
    fn roundtrip_poisson2d() {
        roundtrip(|| poisson2d::<f64>(20, 20), &small_cfg(64));
    }

    #[test]
    fn roundtrip_poisson3d() {
        roundtrip(|| poisson3d::<f64>(8, 8, 8), &small_cfg(128));
    }

    #[test]
    fn roundtrip_unstructured() {
        roundtrip(|| unstructured_mesh::<f64>(24, 24, 0.5, 3), &small_cfg(96));
    }

    #[test]
    fn roundtrip_circuit_with_hubs() {
        roundtrip(|| circuit::<f64>(700, 4, 0.03, 9), &small_cfg(64));
    }

    #[test]
    fn roundtrip_non_multiple_dimension() {
        roundtrip(|| poisson2d::<f64>(17, 13), &small_cfg(32));
    }

    #[test]
    fn roundtrip_default_device_sizing() {
        // No override: equations (1)-(2) with the V100 model.
        roundtrip(|| poisson2d::<f64>(30, 30), &PreprocessConfig::default());
    }

    #[test]
    fn roundtrip_all_partition_methods() {
        for method in [
            PartitionMethod::Multilevel,
            PartitionMethod::BfsBand,
            PartitionMethod::IndexBlock,
            PartitionMethod::Random,
        ] {
            let cfg = PreprocessConfig {
                vec_size_override: Some(64),
                partition: PartitionConfig { method, ..Default::default() },
                ..Default::default()
            };
            roundtrip(|| poisson2d::<f64>(16, 16), &cfg);
        }
    }

    #[test]
    fn roundtrip_without_descending_sort() {
        let cfg = PreprocessConfig {
            vec_size_override: Some(64),
            sort_descending: false,
            ..Default::default()
        };
        roundtrip(|| unstructured_mesh::<f64>(16, 16, 0.5, 5), &cfg);
    }

    #[test]
    fn descending_sort_reduces_fill() {
        let m = unstructured_mesh::<f64>(32, 32, 1.0, 11);
        let on = EhybPlan::build(&m, &small_cfg(128)).unwrap();
        let off = EhybPlan::build(
            &m,
            &PreprocessConfig {
                vec_size_override: Some(128),
                sort_descending: false,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(
            on.matrix.ell_fill_ratio() <= off.matrix.ell_fill_ratio(),
            "sorted fill {} > unsorted {}",
            on.matrix.ell_fill_ratio(),
            off.matrix.ell_fill_ratio()
        );
    }

    #[test]
    fn multilevel_has_lower_er_fraction_than_random() {
        let m = unstructured_mesh::<f64>(32, 32, 0.3, 13);
        let mk = |method| {
            let cfg = PreprocessConfig {
                vec_size_override: Some(128),
                partition: PartitionConfig { method, ..Default::default() },
                ..Default::default()
            };
            EhybPlan::build(&m, &cfg).unwrap().matrix.er_fraction()
        };
        let ml = mk(PartitionMethod::Multilevel);
        let rd = mk(PartitionMethod::Random);
        assert!(ml < rd, "multilevel {ml} >= random {rd}");
    }

    #[test]
    fn timings_populated() {
        let m = poisson2d::<f64>(24, 24);
        let plan = EhybPlan::build(&m, &small_cfg(64)).unwrap();
        assert!(plan.timings.partition_secs >= 0.0);
        assert!(plan.timings.reorder_secs > 0.0);
    }

    #[test]
    fn rejects_non_square() {
        use crate::sparse::coo::Coo;
        let m = Coo::<f64>::new(3, 4).to_csr();
        assert!(EhybPlan::build(&m, &PreprocessConfig::default()).is_err());
    }

    #[test]
    fn u16_cols_within_partition() {
        let m = poisson2d::<f64>(24, 24);
        let plan = EhybPlan::build(&m, &small_cfg(64)).unwrap();
        assert!(plan.matrix.ell_cols.iter().all(|&c| (c as usize) < 64));
    }

    #[test]
    fn ell_width_cutoff_caps_slices_and_stays_correct() {
        let m = circuit::<f64>(700, 4, 0.03, 9); // hub rows force wide slices
        for cut in [1u32, 2, 3] {
            let cfg = PreprocessConfig {
                vec_size_override: Some(64),
                ell_width_cutoff: Some(cut),
                ..Default::default()
            };
            roundtrip(|| circuit::<f64>(700, 4, 0.03, 9), &cfg);
            let plan = EhybPlan::build(&m, &cfg).unwrap();
            assert!(
                plan.matrix.slice_width.iter().all(|&w| w <= cut),
                "cut={cut}: slice width {} exceeds cutoff",
                plan.matrix.slice_width.iter().max().unwrap()
            );
        }
    }

    #[test]
    fn ell_width_cutoff_none_is_bit_identical_to_default() {
        let m = unstructured_mesh::<f64>(24, 24, 0.5, 3);
        let a = EhybPlan::build(&m, &small_cfg(96)).unwrap();
        let b = EhybPlan::build(
            &m,
            &PreprocessConfig { ell_width_cutoff: None, ..small_cfg(96) },
        )
        .unwrap();
        assert_eq!(a.matrix, b.matrix);
    }

    #[test]
    fn ell_width_cutoff_zero_rejected() {
        let m = poisson2d::<f64>(8, 8);
        let cfg = PreprocessConfig {
            vec_size_override: Some(32),
            ell_width_cutoff: Some(0),
            ..Default::default()
        };
        assert!(EhybPlan::build(&m, &cfg).is_err());
    }

    #[test]
    fn ell_width_cutoff_trades_fill_for_er() {
        // Clamping heavy rows must not increase the padded-slot count
        // and must move the excess into ER.
        let m = circuit::<f64>(700, 4, 0.03, 9);
        let base = EhybPlan::build(&m, &small_cfg(64)).unwrap();
        let cut = EhybPlan::build(
            &m,
            &PreprocessConfig {
                vec_size_override: Some(64),
                ell_width_cutoff: Some(2),
                ..Default::default()
            },
        )
        .unwrap();
        assert!(cut.matrix.ell_vals.len() <= base.matrix.ell_vals.len());
        assert!(cut.matrix.er_nnz >= base.matrix.er_nnz);
        assert_eq!(cut.matrix.nnz(), base.matrix.nnz());
    }
}
