//! Paper §3.3 equations (1)–(2): choose the partition count `K × P` and
//! the input-vector cache size `VecSize`.
//!
//! > K = MIN_{K∈Z} ( dimension × τ / (K × P) < SHM_max )
//! > VecSize = dimension / (K × P)
//!
//! τ is the element width, P the processor count. Intent: use *all*
//! compute units (partitions a multiple of P) while making each
//! partition's x-slice as large as fits the scratchpad — bigger slices
//! mean fewer partitions, fewer cut edges, a smaller ER part.

use crate::sparse::scalar::Scalar;

/// Device parameters that feed the sizing equations and the GPU cost
/// model. Defaults model the paper's Tesla V100-SXM2.
#[derive(Clone, Debug)]
pub struct DeviceParams {
    /// Streaming-multiprocessor (or TPU-core) count — the paper's P.
    pub processors: usize,
    /// Usable scratchpad bytes per block (V100: 96 KiB shared memory;
    /// the paper reserves it entirely for the x-slice cache).
    pub shm_bytes: usize,
}

impl DeviceParams {
    /// Tesla V100-SXM2: 80 SMs, 96 KiB shared memory per SM.
    pub fn v100() -> Self {
        Self { processors: 80, shm_bytes: 96 * 1024 }
    }

    /// TPU-core analogue used by the L1 Pallas kernel: treat one core's
    /// VMEM budget for the cached x-slice as 512 KiB out of ~16 MiB
    /// (the rest holds the ELL value/col blocks being streamed), with
    /// 2 cores standing in for "processors" on the single-host testbed.
    pub fn tpu_core() -> Self {
        Self { processors: 2, shm_bytes: 512 * 1024 }
    }
}

/// Result of the sizing equations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CachePlan {
    /// Paper's K (0 when VecSize was overridden).
    pub k: usize,
    /// Rows of x cached per partition (multiple of the slice height,
    /// ≤ 2^16 so column indices fit u16 — §3.4).
    pub vec_size: usize,
    /// Partition count = ceil(n / vec_size) ≈ K × P.
    pub num_parts: usize,
}

/// Apply equations (1)–(2), then round `VecSize` to hardware constraints:
/// a multiple of `slice_height`, at most 2¹⁶ (u16 columns), at least one
/// slice.
pub fn cache_plan<S: Scalar>(n: usize, slice_height: usize, dev: &DeviceParams) -> CachePlan {
    let tau = S::BYTES;
    let p = dev.processors.max(1);
    // Smallest K with n*tau/(K*P) < shm  ⇔  K > n*tau/(shm*P).
    let k = (n * tau) / (dev.shm_bytes * p) + 1;
    let parts_raw = k * p;
    let vec_raw = n.div_ceil(parts_raw).max(1);
    // Round up to slice height; clamp to the u16 index space.
    let mut vec_size = vec_raw.div_ceil(slice_height) * slice_height;
    vec_size = vec_size.min(1 << 16);
    // Shared-memory feasibility after rounding (rounding up can only help
    // K satisfy eq. (1) since VecSize*τ ≤ shm is re-checked here).
    while vec_size * tau > dev.shm_bytes && vec_size > slice_height {
        vec_size -= slice_height;
    }
    let num_parts = n.div_ceil(vec_size);
    CachePlan { k, vec_size, num_parts }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn v100_f32_poisson3d_scale() {
        // Paper-scale example: n = 1,270,432 (atmosmodj), f32 on V100.
        // n*tau = 5.08 MB; shm*P = 96KiB*80 = 7.86 MB => K = 1,
        // VecSize ≈ ceil(n/80) ≈ 15881 -> rounded to 15904.
        let plan = cache_plan::<f32>(1_270_432, 32, &DeviceParams::v100());
        assert_eq!(plan.k, 1);
        assert!(plan.vec_size * 4 < 96 * 1024);
        assert!(plan.vec_size % 32 == 0);
        assert!(plan.num_parts >= 80);
    }

    #[test]
    fn v100_f64_doubles_k_eventually() {
        // f64 doubles τ: for a large enough n, K must grow.
        let n = 10_000_000;
        let p32 = cache_plan::<f32>(n, 32, &DeviceParams::v100());
        let p64 = cache_plan::<f64>(n, 32, &DeviceParams::v100());
        assert!(p64.k >= p32.k);
        assert!(p64.vec_size * 8 <= 96 * 1024);
    }

    #[test]
    fn vec_size_fits_scratchpad() {
        for &n in &[1_000usize, 100_000, 1_000_000, 20_000_000] {
            let plan = cache_plan::<f64>(n, 32, &DeviceParams::v100());
            assert!(plan.vec_size * 8 <= 96 * 1024, "n={n}: {:?}", plan);
            assert_eq!(plan.vec_size % 32, 0);
            assert!(plan.num_parts * plan.vec_size >= n);
        }
    }

    #[test]
    fn u16_bound_respected() {
        // Huge scratchpad would allow VecSize > 2^16; the clamp must hold
        // so §3.4's u16 columns stay valid.
        let dev = DeviceParams { processors: 1, shm_bytes: 1 << 30 };
        let plan = cache_plan::<f32>(1_000_000, 32, &dev);
        assert!(plan.vec_size <= 1 << 16);
    }

    #[test]
    fn tiny_matrix() {
        let plan = cache_plan::<f64>(100, 32, &DeviceParams::v100());
        assert!(plan.vec_size >= 32);
        assert!(plan.num_parts * plan.vec_size >= 100);
    }
}
