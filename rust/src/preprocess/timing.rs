//! Preprocessing time decomposition — the quantity Figure 6 reports in
//! units of a single SpMV.

/// Wall-clock seconds of the two preprocessing phases.
#[derive(Clone, Copy, Debug, Default)]
pub struct PreprocessTimings {
    /// Graph partitioning (Algorithm 1 line 2).
    pub partition_secs: f64,
    /// Counting, sorting, metadata and the Algorithm 2 scatter.
    pub reorder_secs: f64,
}

impl PreprocessTimings {
    pub fn total_secs(&self) -> f64 {
        self.partition_secs + self.reorder_secs
    }

    /// Express the phases as multiples of one SpMV — Figure 6's y-axis.
    pub fn in_spmv_units(&self, spmv_secs: f64) -> SpmvUnits {
        let s = spmv_secs.max(1e-12);
        SpmvUnits {
            partition: self.partition_secs / s,
            reorder: self.reorder_secs / s,
            total: self.total_secs() / s,
        }
    }
}

/// Figure 6 data point.
#[derive(Clone, Copy, Debug)]
pub struct SpmvUnits {
    pub partition: f64,
    pub reorder: f64,
    pub total: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn units_scale() {
        let t = PreprocessTimings { partition_secs: 1.0, reorder_secs: 0.25 };
        let u = t.in_spmv_units(0.001);
        assert!((u.partition - 1000.0).abs() < 1e-9);
        assert!((u.reorder - 250.0).abs() < 1e-9);
        assert!((u.total - 1250.0).abs() < 1e-9);
    }

    #[test]
    fn zero_spmv_guarded() {
        let t = PreprocessTimings { partition_secs: 1.0, reorder_secs: 1.0 };
        let u = t.in_spmv_units(0.0);
        assert!(u.total.is_finite());
    }
}
