//! Facade-level acceptance tests for the `SpmvContext` redesign:
//! every engine kind through the context API, bit-identity of both
//! batch entry points (borrowed `VecBatch` views and the deprecated
//! seed-shaped shim), typed error paths, and the service/solver wiring.

use ehyb::coordinator::Jacobi;
use ehyb::coordinator::SolverConfig;
use ehyb::preprocess::PreprocessConfig;
use ehyb::sparse::gen::{poisson2d, unstructured_mesh};
use ehyb::spmv::SpmvEngine;
use ehyb::util::check::assert_allclose;
use ehyb::{BatchBuf, EhybError, EngineKind, SpmvContext};

fn cfg64() -> PreprocessConfig {
    PreprocessConfig { vec_size_override: Some(64), ..Default::default() }
}

#[test]
fn all_engine_kinds_build_and_validate_through_context() {
    let m = unstructured_mesh::<f64>(20, 20, 0.5, 7);
    let n = m.nrows();
    let x: Vec<f64> = (0..n).map(|i| ((i * 13 + 5) % 23) as f64 * 0.125 - 1.0).collect();
    let oracle = m.spmv_f64_oracle(&x);
    for kind in EngineKind::ALL {
        let ctx = SpmvContext::builder(m.clone()).engine(kind).config(cfg64()).build().unwrap();
        let y = ctx.spmv_alloc(&x).unwrap();
        assert_allclose(&y, &oracle, 1e-9, 1e-9).unwrap_or_else(|e| panic!("{kind:?}: {e}"));
        assert_eq!(ctx.engine().nrows(), n);
        assert_eq!(ctx.engine().ncols(), n);
    }
}

#[test]
fn both_batch_paths_bit_identical_on_every_engine() {
    let m = poisson2d::<f64>(18, 14);
    let n = m.nrows();
    let xs: Vec<Vec<f64>> = (0..5)
        .map(|t| (0..n).map(|i| ((i * 7 + t * 11 + 3) % 19) as f64 * 0.25 - 2.0).collect())
        .collect();
    let xrefs: Vec<&[f64]> = xs.iter().map(|v| v.as_slice()).collect();
    for kind in EngineKind::ALL {
        let ctx = SpmvContext::builder(m.clone()).engine(kind).config(cfg64()).build().unwrap();
        let engine = ctx.engine();
        // Borrowed-view path through the context.
        let xbatch = BatchBuf::from_cols(&xrefs).unwrap();
        let mut ybatch = BatchBuf::<f64>::zeros(n, xs.len());
        {
            let mut yv = ybatch.view_mut();
            ctx.spmv_batch(xbatch.view(), &mut yv).unwrap();
        }
        // Deprecated shim with the seed's exact call shape:
        //   let xrefs: Vec<&[f64]> = ...; let mut ys: Vec<Vec<f64>> = ...;
        //   engine.spmv_batch_vecs(&xrefs, &mut ys);
        let mut ys: Vec<Vec<f64>> = vec![Vec::new(); xrefs.len()];
        #[allow(deprecated)]
        engine.spmv_batch_vecs(&xrefs, &mut ys);
        for (b, x) in xs.iter().enumerate() {
            let mut y1 = vec![0.0; n];
            engine.spmv(x, &mut y1);
            assert_eq!(ybatch.col(b), &y1[..], "{kind:?}: view path lane {b}");
            assert_eq!(&ys[b][..], &y1[..], "{kind:?}: shim lane {b}");
        }
    }
}

#[test]
fn shim_recycles_preallocated_buffers() {
    // Seed call sites that pass recycled ys buffers keep working.
    let m = poisson2d::<f64>(8, 8);
    let ctx = SpmvContext::builder(m).engine(EngineKind::CsrScalar).build().unwrap();
    let xs: Vec<Vec<f64>> = vec![vec![1.0; 64], vec![2.0; 64]];
    let xrefs: Vec<&[f64]> = xs.iter().map(|v| v.as_slice()).collect();
    let mut ys: Vec<Vec<f64>> = vec![vec![9.0; 64], vec![9.0; 3]]; // wrong sizes on purpose
    #[allow(deprecated)]
    ctx.engine().spmv_batch_vecs(&xrefs, &mut ys);
    assert!(ys.iter().all(|y| y.len() == 64));
    for i in 0..64 {
        assert!((ys[1][i] - 2.0 * ys[0][i]).abs() < 1e-12); // linearity
    }
}

#[test]
fn service_stopped_is_typed() {
    let ctx = SpmvContext::builder(poisson2d::<f64>(8, 8)).config(cfg64()).build().unwrap();
    let svc = ctx.serve(4).unwrap();
    let client = svc.client();
    assert_eq!(client.nrows(), 64);
    let y = client.spmv(vec![1.0; 64]).unwrap();
    assert_eq!(y.len(), 64);
    drop(svc);
    assert!(matches!(client.spmv(vec![1.0; 64]), Err(EhybError::ServiceStopped)));
}

#[test]
fn solver_and_service_agree_with_direct_engine() {
    let a = poisson2d::<f64>(16, 16);
    let n = a.nrows();
    let ctx = SpmvContext::builder(a.clone()).config(cfg64()).build().unwrap();
    let b: Vec<f64> = (0..n).map(|i| ((i % 11) as f64) / 11.0 - 0.5).collect();
    let pre = Jacobi::new(&a);
    let (x, rep) = ctx.solver().cg(&b, None, &pre, &SolverConfig::default()).unwrap();
    assert!(rep.converged());
    let mut ax = vec![0.0; n];
    a.spmv(&x, &mut ax);
    assert_allclose(&ax, &b, 1e-6, 1e-6).unwrap();
    // bicgstab path too (works on SPD systems as well).
    let (x2, rep2) = ctx.solver().bicgstab(&b, None, &pre, &SolverConfig::default()).unwrap();
    assert!(rep2.converged());
    let mut ax2 = vec![0.0; n];
    a.spmv(&x2, &mut ax2);
    assert_allclose(&ax2, &b, 1e-6, 1e-6).unwrap();
}

#[test]
fn auto_is_deterministic_and_concrete() {
    let m = poisson2d::<f64>(24, 24);
    let k1 = SpmvContext::builder(m.clone()).engine(EngineKind::Auto).build().unwrap().kind();
    let k2 = SpmvContext::builder(m).engine(EngineKind::Auto).build().unwrap().kind();
    assert_eq!(k1, k2);
    assert_ne!(k1, EngineKind::Auto);
}
