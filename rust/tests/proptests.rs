//! Property-based tests over randomly generated matrices and partitions
//! (custom harness in `ehyb::util::check` — proptest is not in the
//! offline dependency closure; failures reproduce from the printed
//! seed). Cases default to 64 per property; override with
//! EHYB_PROPTEST_CASES.

use ehyb::api::all_contexts;
use ehyb::partition::{partition_graph, Graph, PartitionConfig, PartitionMethod};
use ehyb::preprocess::{EhybPlan, PreprocessConfig};
use ehyb::sparse::coo::Coo;
use ehyb::sparse::csr::Csr;
use ehyb::spmv::SpmvEngine;
use ehyb::util::check::{assert_allclose, check_prop, default_cases};
use ehyb::util::Xoshiro256;
use ehyb::{BatchBuf, EhybError, EngineKind, SpmvContext};

/// Random square matrix: mixes local band structure with global
/// scatter, random degree distribution, possible empty rows.
fn random_matrix(rng: &mut Xoshiro256) -> Csr<f64> {
    let n = 16 + rng.next_below(400);
    let mut coo = Coo::new(n, n);
    for i in 0..n {
        if rng.next_f64() < 0.05 {
            continue; // empty row
        }
        coo.push(i, i, rng.range_f64(1.0, 4.0)); // keep a diagonal
        let deg = rng.next_below(12);
        for _ in 0..deg {
            let j = if rng.next_f64() < 0.6 {
                // local
                let span = 24.min(n);
                (i + rng.next_below(span)).saturating_sub(span / 2).min(n - 1)
            } else {
                rng.next_below(n)
            };
            coo.push(i, j, rng.range_f64(-1.0, 1.0));
        }
    }
    coo.to_csr()
}

fn random_x(rng: &mut Xoshiro256, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.range_f64(-2.0, 2.0)).collect()
}

#[test]
fn prop_all_engines_match_oracle() {
    check_prop("engines-match-oracle", 0xE41B, default_cases(), |rng| {
        let m = random_matrix(rng);
        let vec_size = 32 * (1 + rng.next_below(4));
        let cfg = PreprocessConfig { vec_size_override: Some(vec_size), ..Default::default() };
        let ctxs = all_contexts(&m, &cfg).map_err(|e| format!("build: {e:#}"))?;
        let x = random_x(rng, m.ncols());
        let oracle = m.spmv_f64_oracle(&x);
        for ctx in &ctxs {
            if let Some(plan) = ctx.plan() {
                plan.matrix.validate().map_err(|e| format!("validate: {e:#}"))?;
            }
            let e = ctx.engine();
            let mut y = vec![0.0; m.nrows()];
            e.spmv(&x, &mut y);
            assert_allclose(&y, &oracle, 1e-9, 1e-9).map_err(|err| format!("{}: {err}", e.name()))?;
        }
        Ok(())
    });
}

#[test]
fn prop_spmv_batch_matches_repeated_spmv_all_engines() {
    // Both batched entries — the borrowed-view spmv_batch and the
    // deprecated spmv_batch_vecs shim — must be element-wise identical
    // to looping the single-vector kernel, for every engine kind
    // (the default impl trivially; the EHYB blocked SpMM by
    // keeping per-row accumulation order).
    check_prop("spmv-batch-equals-repeated", 0xBA7C4, default_cases(), |rng| {
        let m = random_matrix(rng);
        let vec_size = 32 * (1 + rng.next_below(4));
        let cfg = PreprocessConfig { vec_size_override: Some(vec_size), ..Default::default() };
        let ctxs = all_contexts(&m, &cfg).map_err(|e| format!("build: {e:#}"))?;
        let bw = 1 + rng.next_below(6);
        let xs: Vec<Vec<f64>> = (0..bw).map(|_| random_x(rng, m.ncols())).collect();
        let xrefs: Vec<&[f64]> = xs.iter().map(|v| v.as_slice()).collect();
        let xbatch = BatchBuf::from_cols(&xrefs).map_err(|e| e.to_string())?;
        for ctx in &ctxs {
            let e = ctx.engine();
            let mut ybatch = BatchBuf::<f64>::zeros(m.nrows(), bw);
            {
                let mut yv = ybatch.view_mut();
                e.spmv_batch(xbatch.view(), &mut yv);
            }
            for (b, x) in xs.iter().enumerate() {
                let mut y1 = vec![0.0; m.nrows()];
                e.spmv(x, &mut y1);
                if y1[..] != *ybatch.col(b) {
                    return Err(format!("{}: batch lane {b} != single spmv (B={bw})", e.name()));
                }
            }
            // Deprecated shim: seed-shaped call sites must still work
            // and stay bit-identical to the view path.
            let mut ys: Vec<Vec<f64>> = vec![Vec::new(); bw];
            #[allow(deprecated)]
            e.spmv_batch_vecs(&xrefs, &mut ys);
            for (b, yb) in ys.iter().enumerate() {
                if yb[..] != *ybatch.col(b) {
                    return Err(format!("{}: shim lane {b} != view path (B={bw})", e.name()));
                }
            }
        }
        Ok(())
    });
}

/// Random square matrix whose columns are mostly *global* scatter, so a
/// small vec_size pushes a large fraction of nnz into the ER part —
/// the stress shape for the parallel ER scatter.
fn random_er_heavy_matrix(rng: &mut Xoshiro256) -> Csr<f64> {
    let n = 128 + rng.next_below(400);
    let mut coo = Coo::new(n, n);
    for i in 0..n {
        coo.push(i, i, rng.range_f64(1.0, 4.0));
        let deg = 1 + rng.next_below(9);
        for _ in 0..deg {
            // 90% global columns: almost everything leaves its partition.
            let j = if rng.next_f64() < 0.9 {
                rng.next_below(n)
            } else {
                (i + rng.next_below(8)).min(n - 1)
            };
            coo.push(i, j, rng.range_f64(-1.0, 1.0));
        }
    }
    coo.to_csr()
}

#[test]
fn prop_parallel_ehyb_bit_identical_er_heavy() {
    // ROADMAP follow-up: the ER tail is now partition-parallel too.
    // On matrices where most nnz land in ER, the threaded scatter must
    // stay bit-identical to the serial kernel.
    check_prop("parallel-ehyb-bitwise-er-heavy", 0x9A11E3, default_cases(), |rng| {
        let m = random_er_heavy_matrix(rng);
        let cfg = PreprocessConfig { vec_size_override: Some(32), ..Default::default() };
        let plan = EhybPlan::build(&m, &cfg).map_err(|e| e.to_string())?;
        if plan.matrix.er_fraction() < 0.3 {
            return Err(format!("generator not ER-heavy: {}", plan.matrix.er_fraction()));
        }
        let engine = ehyb::spmv::ehyb_cpu::EhybCpu::new(&plan);
        let xp = plan.matrix.permute_x(&random_x(rng, m.nrows()));
        let padded = plan.matrix.padded_rows();
        let mut y_ser = vec![0.0; padded];
        let mut y_par = vec![0.0; padded];
        engine.spmv_new_order(&xp, &mut y_ser);
        engine.spmv_new_order_parallel(&xp, &mut y_par);
        if y_ser != y_par {
            return Err(format!(
                "parallel ER scatter not bit-identical (er_nnz={}, er_slices={})",
                plan.matrix.er_nnz,
                plan.matrix.er_slice_width.len()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_dimension_mismatch_typed_on_every_engine() {
    // Wrong-length x/y through the context API must return
    // EhybError::DimensionMismatch — never panic — on all 8 engines.
    check_prop("typed-dimension-mismatch", 0xD1360, 16, |rng| {
        let m = random_matrix(rng);
        let n = m.nrows();
        let vec_size = 32 * (1 + rng.next_below(4));
        let cfg = PreprocessConfig { vec_size_override: Some(vec_size), ..Default::default() };
        for kind in EngineKind::ALL {
            let ctx = SpmvContext::builder(m.clone())
                .engine(kind)
                .config(cfg.clone())
                .build()
                .map_err(|e| format!("{kind:?}: build: {e}"))?;
            // Off-by-k lengths in both directions, both arguments.
            let delta = 1 + rng.next_below(5);
            let bad_lens = [n.saturating_sub(delta), n + delta];
            for &bad in &bad_lens {
                let x = vec![0.0; bad];
                let mut y = vec![0.0; n];
                match ctx.spmv(&x, &mut y) {
                    Err(EhybError::DimensionMismatch { .. }) => {}
                    other => {
                        return Err(format!("{kind:?}: bad x len {bad}: got {other:?}"));
                    }
                }
                let x = vec![0.0; n];
                let mut y = vec![0.0; bad];
                match ctx.spmv(&x, &mut y) {
                    Err(EhybError::DimensionMismatch { .. }) => {}
                    other => {
                        return Err(format!("{kind:?}: bad y len {bad}: got {other:?}"));
                    }
                }
            }
            // Correct lengths still work.
            let x = random_x(rng, n);
            let mut y = vec![0.0; n];
            ctx.spmv(&x, &mut y).map_err(|e| format!("{kind:?}: good dims failed: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_ehyb_bit_identical_f64() {
    check_prop("parallel-ehyb-bitwise-f64", 0x9A11E1, default_cases(), |rng| {
        let m = random_matrix(rng);
        let cfg = PreprocessConfig { vec_size_override: Some(64), ..Default::default() };
        let plan = EhybPlan::build(&m, &cfg).map_err(|e| e.to_string())?;
        let engine = ehyb::spmv::ehyb_cpu::EhybCpu::new(&plan);
        let xp = plan.matrix.permute_x(&random_x(rng, m.nrows()));
        let padded = plan.matrix.padded_rows();
        let mut y_ser = vec![0.0; padded];
        let mut y_par = vec![0.0; padded];
        engine.spmv_new_order(&xp, &mut y_ser);
        engine.spmv_new_order_parallel(&xp, &mut y_par);
        if y_ser != y_par {
            return Err("parallel ELL walk not bit-identical (f64)".into());
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_ehyb_bit_identical_f32() {
    check_prop("parallel-ehyb-bitwise-f32", 0x9A11E2, default_cases(), |rng| {
        let m: Csr<f32> = random_matrix(rng).cast();
        let cfg = PreprocessConfig { vec_size_override: Some(64), ..Default::default() };
        let plan = EhybPlan::build(&m, &cfg).map_err(|e| e.to_string())?;
        let engine = ehyb::spmv::ehyb_cpu::EhybCpu::new(&plan);
        let x: Vec<f32> = random_x(rng, m.nrows()).iter().map(|&v| v as f32).collect();
        let xp = plan.matrix.permute_x(&x);
        let padded = plan.matrix.padded_rows();
        let mut y_ser = vec![0.0f32; padded];
        let mut y_par = vec![0.0f32; padded];
        engine.spmv_new_order(&xp, &mut y_ser);
        engine.spmv_new_order_parallel(&xp, &mut y_par);
        if y_ser != y_par {
            return Err("parallel ELL walk not bit-identical (f32)".into());
        }
        Ok(())
    });
}

#[test]
fn prop_spmv_linearity() {
    check_prop("spmv-linearity", 0x11AA, default_cases(), |rng| {
        let m = random_matrix(rng);
        let cfg = PreprocessConfig { vec_size_override: Some(64), ..Default::default() };
        let plan = EhybPlan::build(&m, &cfg).map_err(|e| e.to_string())?;
        let engine = ehyb::spmv::ehyb_cpu::EhybCpu::new(&plan);
        let n = m.nrows();
        let x = random_x(rng, n);
        let z = random_x(rng, n);
        let (a, b) = (rng.range_f64(-3.0, 3.0), rng.range_f64(-3.0, 3.0));
        let combo: Vec<f64> = x.iter().zip(&z).map(|(xi, zi)| a * xi + b * zi).collect();
        let mut y_combo = vec![0.0; n];
        engine.spmv(&combo, &mut y_combo);
        let mut yx = vec![0.0; n];
        let mut yz = vec![0.0; n];
        engine.spmv(&x, &mut yx);
        engine.spmv(&z, &mut yz);
        let lin: Vec<f64> = yx.iter().zip(&yz).map(|(p, q)| a * p + b * q).collect();
        assert_allclose(&y_combo, &lin, 1e-8, 1e-8)
    });
}

#[test]
fn prop_partition_invariants() {
    check_prop("partition-invariants", 0x9A77, default_cases(), |rng| {
        let m = random_matrix(rng);
        let g = Graph::from_matrix_structure(&m);
        let n = g.nvtx();
        let cap = 32 * (1 + rng.next_below(4)) as u64;
        let k = (n as u64).div_ceil(cap) as usize + rng.next_below(3);
        let method = match rng.next_below(4) {
            0 => PartitionMethod::Multilevel,
            1 => PartitionMethod::BfsBand,
            2 => PartitionMethod::IndexBlock,
            _ => PartitionMethod::Random,
        };
        let r = partition_graph(
            &g,
            k,
            cap,
            &PartitionConfig { method, seed: rng.next_u64(), ..Default::default() },
        );
        // 1. Every vertex assigned a valid part.
        if !r.assignment.iter().all(|&p| (p as usize) < k) {
            return Err("assignment out of range".into());
        }
        // 2. Hard capacity respected.
        for (p, &load) in r.loads.iter().enumerate() {
            if load > cap {
                return Err(format!("part {p} load {load} > cap {cap} ({method:?})"));
            }
        }
        // 3. Loads account for every vertex.
        if r.loads.iter().sum::<u64>() != n as u64 {
            return Err("loads do not sum to n".into());
        }
        // 4. Reported edgecut equals a fresh count.
        if r.edgecut != g.edgecut(&r.assignment) {
            return Err("edgecut mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_preprocess_structure_invariants() {
    check_prop("preprocess-invariants", 0xBEEF, default_cases(), |rng| {
        let m = random_matrix(rng);
        let cfg = PreprocessConfig {
            vec_size_override: Some(32 * (1 + rng.next_below(3))),
            sort_descending: rng.next_below(2) == 0,
            ..Default::default()
        };
        let plan = EhybPlan::build(&m, &cfg).map_err(|e| e.to_string())?;
        let e = &plan.matrix;
        e.validate().map_err(|err| format!("validate: {err:#}"))?;
        // nnz conservation.
        if e.nnz() != m.nnz() {
            return Err(format!("nnz {} != {}", e.nnz(), m.nnz()));
        }
        // Permutation is a bijection on [0, n).
        let mut seen = vec![false; e.padded_rows()];
        for &p in &e.perm {
            if seen[p as usize] {
                return Err("perm not injective".into());
            }
            seen[p as usize] = true;
        }
        // Slice widths bound the rows they contain (via fill ratio ≥ 1).
        if e.ell_fill_ratio() < 1.0 - 1e-12 {
            return Err(format!("fill ratio {} < 1", e.ell_fill_ratio()));
        }
        Ok(())
    });
}

#[test]
fn prop_permute_roundtrip() {
    check_prop("permute-roundtrip", 0x7777, default_cases(), |rng| {
        let m = random_matrix(rng);
        let cfg = PreprocessConfig { vec_size_override: Some(64), ..Default::default() };
        let plan = EhybPlan::build(&m, &cfg).map_err(|e| e.to_string())?;
        let x = random_x(rng, m.nrows());
        let xp = plan.matrix.permute_x(&x);
        let back = plan.matrix.unpermute_y(&xp);
        assert_allclose(&back, &x, 0.0, 0.0)
    });
}

#[test]
fn prop_mmio_roundtrip() {
    check_prop("mmio-roundtrip", 0x31337, 16, |rng| {
        let m = random_matrix(rng);
        let dir = std::env::temp_dir().join("ehyb_proptests");
        std::fs::create_dir_all(&dir).map_err(|e| e.to_string())?;
        let path = dir.join(format!("rt_{}.mtx", rng.next_u64()));
        ehyb::sparse::mmio::write_matrix_market(&m.to_coo(), &path).map_err(|e| e.to_string())?;
        let m2: Csr<f64> = ehyb::sparse::mmio::read_matrix_market::<f64, _>(&path)
            .map_err(|e| e.to_string())?
            .to_csr();
        std::fs::remove_file(&path).ok();
        if m2.nnz() != m.nnz() {
            return Err(format!("nnz {} != {}", m2.nnz(), m.nnz()));
        }
        let x = random_x(rng, m.ncols());
        assert_allclose(&m2.spmv_f64_oracle(&x), &m.spmv_f64_oracle(&x), 1e-12, 1e-12)
    });
}

#[test]
fn prop_l2_sim_sanity() {
    // Hit rate rises monotonically with capacity for a looping pattern.
    check_prop("l2-monotone-capacity", 0xCAFE, 16, |rng| {
        use ehyb::gpu::l2::L2Sim;
        let working_set = 256 + rng.next_below(2048) as u64;
        let mut last_rate = -1.0f64;
        for cap_kb in [8usize, 32, 128, 512] {
            let mut l2 = L2Sim::new(cap_kb * 1024, 32);
            for _ in 0..4 {
                for s in 0..working_set {
                    l2.access(s);
                }
            }
            let rate = l2.hit_rate();
            if rate + 1e-9 < last_rate {
                return Err(format!("hit rate fell: {last_rate} -> {rate} at {cap_kb}KiB"));
            }
            last_rate = rate;
        }
        Ok(())
    });
}

#[test]
fn prop_nan_fault_is_typed_or_propagated_never_panic() {
    // ISSUE 6 satellite: a NaN planted by the deterministic fault
    // injector must either be rejected with a typed NonFinite naming
    // the poisoned index (Reject guard) or complete without panicking
    // (default, unguarded) — on every engine kind.
    use ehyb::{FaultInjector, FaultPlan, GuardLevel};
    check_prop("nan-fault-typed-all-engines", 0xFA5EED, 12, |rng| {
        let m = random_matrix(rng);
        let n = m.nrows();
        let vec_size = 32 * (1 + rng.next_below(4));
        let cfg = PreprocessConfig { vec_size_override: Some(vec_size), ..Default::default() };
        let plan = FaultPlan { nan_on_call: Some(1), ..FaultPlan::from_seed(rng.next_u64()) };
        let inj = FaultInjector::new(plan);
        let mut x = random_x(rng, n);
        let idx = inj.poison(1, &mut x).ok_or("empty x")?;
        for kind in EngineKind::ALL {
            let rctx = SpmvContext::builder(m.clone())
                .engine(kind)
                .config(cfg.clone())
                .guard(GuardLevel::Reject)
                .build()
                .map_err(|e| format!("{kind:?}: build: {e}"))?;
            let mut y = vec![0.0; n];
            match rctx.spmv(&x, &mut y) {
                Err(EhybError::NonFinite { what: "x", index }) if index == idx => {}
                other => {
                    return Err(format!("{kind:?}: expected NonFinite at {idx}, got {other:?}"));
                }
            }
            if rctx.health().rejected_inputs != 1 {
                return Err(format!("{kind:?}: rejection not recorded in health"));
            }
            // Unguarded: the poisoned SpMV still completes (NaN may
            // propagate into y, but never a panic or a hang).
            let ctx = SpmvContext::builder(m.clone())
                .engine(kind)
                .config(cfg.clone())
                .build()
                .map_err(|e| format!("{kind:?}: build: {e}"))?;
            let mut y = vec![0.0; n];
            ctx.spmv(&x, &mut y).map_err(|e| format!("{kind:?}: unguarded spmv: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_solver_solves_spd() {
    check_prop("cg-solves-spd", 0x50D, 12, |rng| {
        // Random SPD: symmetrize values (A+Aᵀ)/2, then make it strictly
        // diagonally dominant — symmetric + dominant ⇒ positive definite.
        let m = random_matrix(rng);
        let mut coo = Coo::<f64>::new(m.nrows(), m.ncols());
        for i in 0..m.nrows() {
            let (cols, vals) = m.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                coo.push(i, c as usize, 0.5 * v);
                coo.push(c as usize, i, 0.5 * v);
            }
        }
        let a = ehyb::sparse::gen::diag_dominant(&coo.to_csr());
        let n = a.nrows();
        let b = random_x(rng, n);
        let pre = ehyb::coordinator::Jacobi::new(&a);
        let (x, rep) = ehyb::coordinator::cg(
            |v, y: &mut [f64]| a.spmv(v, y),
            &b,
            &vec![0.0; n],
            &pre,
            &ehyb::coordinator::SolverConfig { max_iters: 4000, ..Default::default() },
        );
        if !rep.converged() {
            return Err(format!("CG failed: {rep:?}"));
        }
        let mut ax = vec![0.0; n];
        a.spmv(&x, &mut ax);
        assert_allclose(&ax, &b, 1e-5, 1e-6)
    });
}
