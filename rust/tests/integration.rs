//! Cross-module integration tests: the full pipeline (generator → graph
//! partitioner → preprocessing → engines → solver → harness) on real
//! workloads, no PJRT required (that path is covered in runtime_pjrt.rs).

use ehyb::coordinator::{bicgstab, cg, Jacobi, Spai0, SolverConfig};
use ehyb::gpu::GpuDevice;
use ehyb::harness::{runner, suite};
use ehyb::preprocess::{EhybPlan, PreprocessConfig};
use ehyb::sparse::csr::Csr;
use ehyb::sparse::gen;
use ehyb::sparse::mmio;
use ehyb::spmv::SpmvEngine;
use ehyb::util::check::assert_allclose;
use ehyb::{EngineKind, SpmvContext};

fn x_for(n: usize) -> Vec<f64> {
    (0..n).map(|i| ((i * 29 + 13) % 31) as f64 * 0.125 - 1.5).collect()
}

#[test]
fn full_pipeline_all_engines_agree_across_generators() {
    let matrices: Vec<(&str, Csr<f64>)> = vec![
        ("poisson2d", gen::poisson2d(23, 19)),
        ("poisson3d", gen::poisson3d(9, 8, 7)),
        ("stencil27", gen::stencil27(7, 7, 7, 3)),
        ("elasticity", gen::elasticity3d(4, 4, 4, 3, 5)),
        ("unstructured", gen::unstructured_mesh(20, 20, 0.6, 7)),
        ("circuit", gen::circuit(600, 4, 0.03, 9)),
        ("kkt", gen::kkt(6, 11)),
        ("banded", gen::banded(500, 9, 0.5, 13)),
    ];
    for (name, m) in matrices {
        let cfg = PreprocessConfig { vec_size_override: Some(64), ..Default::default() };
        // One context per engine kind — the single engine-construction
        // path now that spmv::registry is retired.
        let ctxs = ehyb::api::all_contexts(&m, &cfg).unwrap();
        assert_eq!(ctxs.len(), EngineKind::ALL.len(), "{name}");
        let x = x_for(m.ncols());
        let oracle = m.spmv_f64_oracle(&x);
        for ctx in &ctxs {
            if let Some(plan) = ctx.plan() {
                plan.matrix.validate().unwrap();
            }
            let e = ctx.engine();
            let mut y = vec![0.0; m.nrows()];
            e.spmv(&x, &mut y);
            assert_allclose(&y, &oracle, 1e-9, 1e-9)
                .unwrap_or_else(|err| panic!("{name}/{}: {err}", e.name()));
        }
    }
}

#[test]
fn full_pipeline_sharded_across_generators() {
    // The row-sharded engine through the same generator sweep: every
    // kind's sharded context must agree with the oracle (the bitwise
    // sharded-vs-unsharded contract itself is pinned in tests/shard.rs).
    let matrices: Vec<(&str, Csr<f64>)> = vec![
        ("poisson2d", gen::poisson2d(23, 19)),
        ("circuit", gen::circuit(600, 4, 0.03, 9)),
        ("banded", gen::banded(500, 9, 0.5, 13)),
    ];
    for (name, m) in matrices {
        let cfg = PreprocessConfig { vec_size_override: Some(64), ..Default::default() };
        let x = x_for(m.ncols());
        let oracle = m.spmv_f64_oracle(&x);
        for kind in EngineKind::ALL {
            if kind == EngineKind::Ell && m.max_row_nnz() * m.nrows() > 16 * m.nnz() {
                continue; // same padding guard the engine sweeps apply
            }
            let ctx = SpmvContext::builder(m.clone())
                .engine(kind)
                .config(cfg.clone())
                .shards(ehyb::ShardSpec::Count(4))
                .build()
                .unwrap_or_else(|e| panic!("{name}/{kind:?}: {e:#}"));
            assert_eq!(ctx.shards(), 4, "{name}/{kind:?}");
            let y = ctx.spmv_alloc(&x).unwrap();
            assert_allclose(&y, &oracle, 1e-9, 1e-9)
                .unwrap_or_else(|err| panic!("{name}/{kind:?}: {err}"));
        }
    }
}

#[test]
fn mmio_roundtrip_through_full_pipeline() {
    let m = gen::unstructured_mesh::<f64>(16, 16, 0.4, 21);
    let dir = std::env::temp_dir().join("ehyb_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("m.mtx");
    mmio::write_matrix_market(&m.to_coo(), &path).unwrap();
    let m2: Csr<f64> = mmio::read_matrix_market::<f64, _>(&path).unwrap().to_csr();
    assert_eq!(m.nnz(), m2.nnz());
    let cfg = PreprocessConfig { vec_size_override: Some(64), ..Default::default() };
    let plan = EhybPlan::build(&m2, &cfg).unwrap();
    let engine = ehyb::spmv::ehyb_cpu::EhybCpu::new(&plan);
    let x = x_for(m.ncols());
    let mut y = vec![0.0; m.nrows()];
    engine.spmv(&x, &mut y);
    assert_allclose(&y, &m.spmv_f64_oracle(&x), 1e-10, 1e-10).unwrap();
    std::fs::remove_file(path).ok();
}

#[test]
fn solvers_match_across_engines() {
    let a = gen::poisson3d::<f64>(7, 7, 7);
    let n = a.nrows();
    let b = x_for(n);
    let cfg = PreprocessConfig { vec_size_override: Some(64), ..Default::default() };
    let plan = EhybPlan::build(&a, &cfg).unwrap();
    let ehyb_engine = ehyb::spmv::ehyb_cpu::EhybCpu::new(&plan);
    let pre = Jacobi::new(&a);
    let scfg = SolverConfig::default();
    let (x1, r1) = cg(|v, y: &mut [f64]| a.spmv(v, y), &b, &vec![0.0; n], &pre, &scfg);
    let (x2, r2) = cg(|v, y: &mut [f64]| ehyb_engine.spmv(v, y), &b, &vec![0.0; n], &pre, &scfg);
    assert!(r1.converged() && r2.converged());
    assert_allclose(&x1, &x2, 1e-6, 1e-8).unwrap();
}

#[test]
fn bicgstab_spai_on_nonsymmetric_through_ehyb() {
    let a = gen::diag_dominant(&gen::circuit::<f64>(800, 4, 0.02, 3));
    let n = a.nrows();
    let cfg = PreprocessConfig { vec_size_override: Some(64), ..Default::default() };
    let plan = EhybPlan::build(&a, &cfg).unwrap();
    let engine = ehyb::spmv::ehyb_cpu::EhybCpu::new(&plan);
    let b = x_for(n);
    let pre = Spai0::new(&a);
    let (x, rep) = bicgstab(
        |v, y: &mut [f64]| engine.spmv(v, y),
        &b,
        &vec![0.0; n],
        &pre,
        &SolverConfig { max_iters: 3000, ..Default::default() },
    );
    assert!(rep.converged(), "{rep:?}");
    let mut ax = vec![0.0; n];
    a.spmv(&x, &mut ax);
    assert_allclose(&ax, &b, 1e-6, 1e-7).unwrap();
}

#[test]
fn service_solver_roundtrip() {
    let a = gen::poisson2d::<f64>(20, 20);
    let n = a.nrows();
    let ctx = SpmvContext::builder(a.clone())
        .engine(EngineKind::Ehyb)
        .config(PreprocessConfig { vec_size_override: Some(64), ..Default::default() })
        .build()
        .unwrap();
    let svc = ctx.serve(8).unwrap();
    let client = svc.client();
    let b = x_for(n);
    let pre = Jacobi::new(&a);
    let (x, rep) = cg(
        |v, y: &mut [f64]| y.copy_from_slice(&client.spmv(v.to_vec()).unwrap()),
        &b,
        &vec![0.0; n],
        &pre,
        &SolverConfig::default(),
    );
    assert!(rep.converged());
    let mut ax = vec![0.0; n];
    a.spmv(&x, &mut ax);
    // rtol-1e-8 solve: entries of b that are exactly 0 need a real atol.
    assert_allclose(&ax, &b, 1e-6, 1e-6).unwrap();
    assert!(svc.metrics.spmv_latency.count() > 0);
}

#[test]
fn context_facade_full_pipeline() {
    // The facade end to end: build once, spmv / batch / service /
    // solver off one prepared handle.
    let a = gen::poisson3d::<f64>(8, 8, 8);
    let n = a.nrows();
    let ctx = SpmvContext::builder(a.clone())
        .engine(EngineKind::Ehyb)
        .config(PreprocessConfig { vec_size_override: Some(64), ..Default::default() })
        .build()
        .unwrap();
    let x = x_for(n);
    let y = ctx.spmv_alloc(&x).unwrap();
    assert_allclose(&y, &a.spmv_f64_oracle(&x), 1e-10, 1e-10).unwrap();

    // Multi-RHS through the solver handle: each system must match a
    // standalone CG solve through the same engine bit-for-bit.
    let pre = Jacobi::new(&a);
    let cfg = SolverConfig::default();
    let bs: Vec<Vec<f64>> = (0..3)
        .map(|t| (0..n).map(|i| ((i * 5 + t * 13 + 1) % 17) as f64 / 17.0 - 0.5).collect())
        .collect();
    let many = ctx.solver().cg_many(&bs, &pre, &cfg).unwrap();
    assert_eq!(many.len(), 3);
    for (i, (xm, rep)) in many.iter().enumerate() {
        assert!(rep.converged(), "system {i}: {rep:?}");
        let (x1, rep1) = ctx.solver().cg(&bs[i], None, &pre, &cfg).unwrap();
        assert_eq!(rep.iters, rep1.iters, "system {i}");
        assert_eq!(xm, &x1, "system {i}");
    }

    // Service round-trip off the same context.
    let svc = ctx.serve(4).unwrap();
    let got = svc.client().spmv(x.clone()).unwrap();
    assert_eq!(got, y);
}

#[test]
fn harness_runner_over_tiny_corpus() {
    // Every suite16 matrix must preprocess and simulate cleanly at Tiny.
    let dev = GpuDevice::v100();
    for spec in suite::suite16(suite::Scale::Tiny) {
        let m = spec.build();
        let run =
            runner::run_matrix(&spec.name, spec.category, &m, &PreprocessConfig::default(), &dev)
                .unwrap_or_else(|e| panic!("{}: {e:#}", spec.name));
        assert!(run.gflops_of("ehyb").unwrap() > 0.0, "{}", spec.name);
        assert!(run.rows.len() >= 6, "{}", spec.name);
        assert!((0.0..=1.0).contains(&run.er_fraction));
    }
}

#[test]
fn equations_1_2_feasible_across_scales() {
    // Equation (1)'s constraint VecSize·τ < SHM holds at every scale,
    // partitions cover the matrix, and f32 never caches fewer rows
    // than f64 (τ is halved).
    use ehyb::preprocess::cache_size::{cache_plan, DeviceParams};
    let dev = DeviceParams::v100();
    for n in [10_000usize, 100_000, 1_000_000, 10_000_000, 50_000_000] {
        let p64 = cache_plan::<f64>(n, 32, &dev);
        assert!(p64.vec_size * 8 <= dev.shm_bytes, "n={n}");
        assert!(p64.num_parts * p64.vec_size >= n, "n={n}");
        let p32 = cache_plan::<f32>(n, 32, &dev);
        assert!(p32.vec_size * 4 <= dev.shm_bytes, "n={n}");
        assert!(p32.vec_size >= p64.vec_size, "f32 cache should fit at least as many rows");
    }
}

#[test]
fn gpu_sim_ordering_stable_across_runs() {
    // The simulator is deterministic: same matrix -> identical report.
    let m = gen::unstructured_mesh::<f64>(32, 32, 0.5, 17);
    let cfg = PreprocessConfig { vec_size_override: Some(128), ..Default::default() };
    let dev = GpuDevice::v100();
    let a = runner::run_matrix("x", "t", &m, &cfg, &dev).unwrap();
    let b = runner::run_matrix("x", "t", &m, &cfg, &dev).unwrap();
    for (ra, rb) in a.rows.iter().zip(&b.rows) {
        assert_eq!(ra.framework, rb.framework);
        assert!((ra.gflops - rb.gflops).abs() < 1e-9);
    }
}
