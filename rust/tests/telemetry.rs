//! Integration gates for the unified telemetry subsystem (PR 8).
//!
//! Everything here runs under [`Telemetry::with_fake_clock`]: the
//! logical tick clock makes span timestamps bit-for-bit reproducible
//! for a deterministic call sequence, so these tests can pin exact
//! span trees (goldens), assert the proptest-style terminal-event
//! invariant across seeded chaos workloads, and check that both
//! exporters are byte-identical across snapshots of a frozen registry.

use ehyb::coordinator::service::{BatchKernel, SpmvService};
use ehyb::coordinator::{Jacobi, SolverConfig};
use ehyb::resilience::{FaultInjector, FaultPlan, RetryPolicy};
use ehyb::sparse::gen;
use ehyb::telemetry::snapshot::TERMINAL_KINDS;
use ehyb::{EngineKind, ShardSpec, SpmvContext, Telemetry, TelemetrySnapshot};
use std::time::{Duration, Instant};

/// Deterministic split-mix step for the proptest-style loops.
fn lcg(state: &mut u64) -> u64 {
    *state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
    *state >> 33
}

fn seeded_x(n: usize, salt: u64) -> Vec<f64> {
    (0..n)
        .map(|i| ((i as u64).wrapping_mul(salt.wrapping_add(3)) % 17) as f64 * 0.25 - 2.0)
        .collect()
}

/// One seeded build + serve on a fake clock. Unsharded: the sharded
/// engine records its per-shard spans from worker threads, whose clock
/// interleaving is not deterministic — byte goldens stay on the serial
/// path, the sharded story is asserted structurally below.
fn build_and_serve(seed: u64) -> SpmvContext<f64> {
    let m = gen::poisson2d::<f64>(8, 8);
    let n = m.nrows();
    let ctx = SpmvContext::builder(m)
        .engine(EngineKind::Ehyb)
        .telemetry(Telemetry::with_fake_clock())
        .build()
        .expect("seeded build");
    let svc = ctx.serve(4).expect("serve");
    let client = svc.client();
    for r in 0..3u64 {
        let y = client.spmv(seeded_x(n, seed.wrapping_add(r))).expect("round trip");
        assert_eq!(y.len(), n);
    }
    drop(svc);
    ctx
}

/// Hand-computed golden: under the fake clock every observation ticks
/// the logical time by exactly 1 ns, so the rendered tree is knowable
/// in advance — this pins the render format *and* the tick discipline.
#[test]
fn hand_built_span_tree_matches_exact_golden() {
    let t = Telemetry::with_fake_clock();
    let tr = t.mint_trace();
    {
        let b = t.span("serve.batch(w=2)"); // id=1, start=tick 1
        let drained = t.now_nanos(); // tick 2
        t.record_span("queue.wait", b.id(), tr, 0, drained);
        let _k = b.child("kernel"); // id=3, start=tick 3; drop -> end=4
    } // batch drop -> end=5
    let golden = "serve.batch(w=2) [1..5ns]\n  queue.wait [0..2ns] trace=1\n  kernel [3..4ns]\n";
    assert_eq!(t.snapshot().span_tree(), golden);
}

/// Two identical seeded build+serve runs render the same span tree,
/// byte for byte, and agree on every structural landmark of the
/// pipeline decomposition.
#[test]
fn seeded_build_and_serve_span_tree_is_reproducible() {
    let a = build_and_serve(7).telemetry_snapshot();
    let b = build_and_serve(7).telemetry_snapshot();
    let tree = a.span_tree();
    assert_eq!(tree, b.span_tree(), "fake-clock span tree must be run-to-run identical");
    assert_eq!(a.known_traces(), b.known_traces());

    // Build side: the root `build` span contains the derived EHYB
    // phase spans; the engine builds lazily at first serve use.
    assert!(tree.starts_with("build ["), "root must be the build span:\n{tree}");
    assert!(tree.contains("\n  ehyb.partition ["), "{tree}");
    assert!(tree.contains("\n  ehyb.assemble ["), "{tree}");
    assert!(tree.contains("\nengine.build ["), "{tree}");

    // Serve side: serial round-trips drain as width-1 batches, each
    // with a trace-tagged queue-wait child and a fused-kernel child.
    assert!(tree.contains("\nserve.batch(w=1) ["), "{tree}");
    assert!(tree.contains("\n  queue.wait ["), "{tree}");
    assert!(tree.contains("] trace=1\n"), "{tree}");
    assert!(tree.contains("\n  kernel ["), "{tree}");

    // A different seed still produces the same *shape* (the seed only
    // changes request payloads, never the instrumentation sequence).
    assert_eq!(tree, build_and_serve(8).telemetry_snapshot().span_tree());
}

/// Collect the traces that were actually submitted to a service (the
/// `submit` event is recorded before queue admission decides between
/// reply / shed / deadline / fault).
fn submitted_traces(snap: &TelemetrySnapshot) -> Vec<u64> {
    let mut v: Vec<u64> =
        snap.events.iter().filter(|e| e.kind == "submit").map(|e| e.trace).collect();
    v.sort_unstable();
    v.dedup();
    v
}

/// Proptest-style invariant: across seeded workloads that exercise
/// every admission outcome — served replies, expired deadlines, shed
/// floods, injected engine faults with retry — every submitted
/// request's trace ID appears in **exactly one** terminal event.
#[test]
fn every_submitted_trace_reaches_exactly_one_terminal_event() {
    for seed in 1..=4u64 {
        let mut rng = seed;
        let m = gen::poisson2d::<f64>(8, 8);
        let n = m.nrows();
        let ctx = SpmvContext::builder(m)
            .engine(EngineKind::Ehyb)
            .telemetry(Telemetry::with_fake_clock())
            .build()
            .expect("build");

        // Scenario A: a few served round-trips plus one pre-expired
        // deadline triaged out at drain time.
        {
            let svc = ctx.serve(4).expect("serve");
            let client = svc.client();
            for r in 0..(1 + lcg(&mut rng) % 3) {
                client.spmv(seeded_x(n, r)).expect("round trip");
            }
            let expired = Instant::now() - Duration::from_millis(5);
            assert!(matches!(
                client.spmv_deadline(seeded_x(n, 9), expired),
                Err(ehyb::EhybError::DeadlineExceeded)
            ));
        }

        // Scenario B: injected engine panic on the first kernel call;
        // bounded retry recovers it (fault terminal + linked retry
        // trace reaching a reply terminal).
        {
            let inj = FaultInjector::new(FaultPlan {
                panic_on_call: Some(1),
                nan_on_call: None,
                ..FaultPlan::from_seed(seed)
            });
            let engine = ctx.engine_arc();
            let svc: SpmvService<f64> = SpmvService::spawn_with_telemetry(
                move || {
                    let engine = engine.clone();
                    let fb = engine.format_bytes();
                    let kernel: BatchKernel<f64> =
                        Box::new(move |xs, ys| engine.spmv_batch(xs, ys));
                    Ok((inj.wrap_kernel(kernel), fb))
                },
                n,
                4,
                64,
                false,
                ctx.telemetry().clone(),
            )
            .expect("spawn");
            let policy = RetryPolicy {
                max_attempts: 3,
                base_delay: Duration::from_micros(50),
                max_delay: Duration::from_micros(400),
                seed,
            };
            svc.client().spmv_with_retry(seeded_x(n, 11), &policy).expect("retry recovers");
        }

        // Scenario C: shed. A gate holds the kernel open on a depth-2
        // queue; once it is full every further submission sheds.
        {
            let engine = ctx.engine_arc();
            let (started_tx, started_rx) = std::sync::mpsc::channel::<()>();
            let (gate_tx, gate_rx) = std::sync::mpsc::channel::<()>();
            let mut rig = Some((started_tx, gate_rx));
            let svc: SpmvService<f64> = SpmvService::spawn_with_telemetry(
                move || {
                    let engine = engine.clone();
                    let fb = engine.format_bytes();
                    let (stx, grx) = rig.take().expect("gated rig builds one engine");
                    let kernel: BatchKernel<f64> = Box::new(move |xs, ys| {
                        stx.send(()).ok();
                        grx.recv().ok();
                        engine.spmv_batch(xs, ys)
                    });
                    Ok((kernel, fb))
                },
                n,
                4,
                2,
                false,
                ctx.telemetry().clone(),
            )
            .expect("spawn gated");
            let client = svc.client();
            let first = client.submit(seeded_x(n, 1)).expect("first request admitted");
            started_rx.recv().expect("kernel reached the gate");
            let mut queued = Vec::new();
            let mut shed = 0u32;
            for s in 0..4 {
                match client.try_submit(seeded_x(n, 20 + s)) {
                    Ok(rx) => queued.push(rx),
                    Err((ehyb::EhybError::Overloaded { .. }, _)) => shed += 1,
                    Err((e, _)) => panic!("unexpected admission error: {e:?}"),
                }
            }
            assert_eq!(queued.len(), 2, "queue bound is 2");
            assert_eq!(shed, 2, "overflow must shed");
            drop(gate_tx); // release the kernel; queued work drains
            first.recv().expect("service alive").expect("gated reply");
            for rx in queued {
                rx.recv().expect("service alive").expect("queued reply");
            }
        }

        let snap = ctx.telemetry_snapshot();
        let submitted = submitted_traces(&snap);
        assert!(submitted.len() >= 8, "seed {seed}: expected a full workload");
        for tr in &submitted {
            assert_eq!(
                snap.terminal_event_count(*tr),
                1,
                "seed {seed}: trace {tr} must reach exactly one terminal event"
            );
        }
        // Every admission outcome is represented.
        for kind in TERMINAL_KINDS {
            assert!(
                snap.events.iter().any(|e| e.kind == kind),
                "seed {seed}: workload should produce a {kind} event"
            );
        }
        // And no terminal event names a trace that was never submitted.
        for e in snap.events.iter().filter(|e| TERMINAL_KINDS.contains(&e.kind.as_str())) {
            assert!(
                submitted.binary_search(&e.trace).is_ok(),
                "seed {seed}: terminal {} for unsubmitted trace {}",
                e.kind,
                e.trace
            );
        }
    }
}

/// Exporter contract: Prometheus text exposition lints clean (every
/// sample under exactly one `# TYPE` header, names sanitized, values
/// parse) and both exporters are byte-identical across two snapshots
/// of a frozen registry.
#[test]
fn exporters_lint_and_freeze_byte_identically() {
    let ctx = build_and_serve(7);
    let snap = ctx.telemetry_snapshot();
    let again = ctx.telemetry_snapshot();
    assert_eq!(
        snap.to_json().dump(),
        again.to_json().dump(),
        "frozen registry must export identical JSON"
    );
    assert_eq!(
        snap.to_prometheus(),
        again.to_prometheus(),
        "frozen registry must export identical Prometheus text"
    );

    // JSON round-trips through the crate's own parser.
    let dump = snap.to_json().dump();
    let reparsed = ehyb::runtime::json::Json::parse(&dump).expect("self-parse");
    assert_eq!(reparsed.dump(), dump);

    // Prometheus lint.
    let prom = snap.to_prometheus();
    let mut types = std::collections::BTreeSet::new();
    for line in prom.lines() {
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let name = rest.split(' ').next().unwrap();
            assert!(types.insert(name.to_string()), "duplicate # TYPE for {name}");
        }
    }
    assert!(!types.is_empty(), "exposition should declare metric types");
    for line in prom.lines().filter(|l| !l.starts_with('#')) {
        let name_end = line.find(['{', ' ']).unwrap_or_else(|| panic!("malformed: {line}"));
        let sample = &line[..name_end];
        assert!(sample.starts_with("ehyb_"), "unprefixed metric: {line}");
        assert!(
            sample.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
            "unsanitized metric name: {line}"
        );
        // Summary `_sum` / `_count` series belong to their base metric.
        let base = if types.contains(sample) {
            sample
        } else {
            sample
                .strip_suffix("_sum")
                .or_else(|| sample.strip_suffix("_count"))
                .unwrap_or(sample)
        };
        assert!(types.contains(base), "sample without # TYPE header: {line}");
        let value = line.rsplit(' ').next().unwrap();
        value.parse::<f64>().unwrap_or_else(|_| panic!("unparseable sample value: {line}"));
    }

    // The serve workload landed in the folded service namespace.
    assert!(prom.contains("ehyb_service_requests{svc=\"0\"} 3\n"), "{prom}");
}

/// Acceptance path: one trace ID reconstructs a request's whole story
/// — submit, queue wait, the fused batch with its per-shard kernel
/// spans, the retry link from the faulted first attempt, and the
/// terminal reply — from a single snapshot of a sharded context.
#[test]
fn one_trace_id_reconstructs_the_whole_request_story() {
    let m = gen::poisson2d::<f64>(8, 8);
    let n = m.nrows();
    let ctx = SpmvContext::builder(m)
        .engine(EngineKind::Ehyb)
        .shards(ShardSpec::Count(2))
        .telemetry(Telemetry::with_fake_clock())
        .build()
        .expect("sharded build");

    let inj = FaultInjector::new(FaultPlan {
        panic_on_call: Some(1),
        nan_on_call: None,
        ..FaultPlan::from_seed(7)
    });
    let engine = ctx.engine_arc();
    let svc: SpmvService<f64> = SpmvService::spawn_with_telemetry(
        move || {
            let engine = engine.clone();
            let fb = engine.format_bytes();
            let kernel: BatchKernel<f64> = Box::new(move |xs, ys| engine.spmv_batch(xs, ys));
            Ok((inj.wrap_kernel(kernel), fb))
        },
        n,
        4,
        64,
        false,
        ctx.telemetry().clone(),
    )
    .expect("spawn");
    let policy = RetryPolicy {
        max_attempts: 3,
        base_delay: Duration::from_micros(50),
        max_delay: Duration::from_micros(400),
        seed: 7,
    };
    svc.client().spmv_with_retry(seeded_x(n, 5), &policy).expect("retry recovers");
    drop(svc);

    let snap = ctx.telemetry_snapshot();
    let retry = snap.events.iter().find(|e| e.kind == "retry").expect("retry event");
    let faulted = snap
        .events
        .iter()
        .find(|e| e.kind == "fault")
        .expect("first attempt faults")
        .trace;
    assert!(retry.detail.contains(&format!("prev={faulted}")), "{}", retry.detail);
    assert_eq!(snap.terminal_event_count(faulted), 1, "fault is the first attempt's terminal");
    assert_eq!(snap.terminal_event_count(retry.trace), 1, "reply is the retry's terminal");

    // The retried attempt's story, from one snapshot, one ID.
    let story = snap.describe_trace(retry.trace);
    assert!(story.contains("submit:"), "{story}");
    assert!(story.contains("retry: attempt=2"), "{story}");
    assert!(story.contains("reply: served in batch width=1"), "{story}");
    assert!(story.contains("queue.wait"), "{story}");
    assert!(story.contains("serve.batch(w=1)"), "{story}");
    assert!(story.contains("kernel"), "{story}");
    assert!(story.contains("shard.kernel(i=0)"), "{story}");
    assert!(story.contains("shard.kernel(i=1)"), "{story}");

    // The faulted attempt's story names its successor.
    let prior = snap.describe_trace(faulted);
    assert!(prior.contains(&format!("retried as trace {}", retry.trace)), "{prior}");
    assert!(prior.contains("fault: engine panic"), "{prior}");
}

/// The solver path feeds the same snapshot: a traced `solve.cg` span
/// with one `solver-iter` event per recorded residual and a
/// `solver-done` summary, all under the same trace.
#[test]
fn solver_iterations_are_traced_into_the_snapshot() {
    let m = gen::poisson2d::<f64>(8, 8);
    let n = m.nrows();
    let ctx = SpmvContext::builder(m)
        .engine(EngineKind::Ehyb)
        .telemetry(Telemetry::with_fake_clock())
        .build()
        .expect("build");
    let b: Vec<f64> = (0..n).map(|i| ((i % 7) as f64) * 0.5 + 0.25).collect();
    let precond = Jacobi::new(ctx.matrix());
    let (_, rep) =
        ctx.solver().cg(&b, None, &precond, &SolverConfig::default()).expect("solve");
    assert!(rep.converged());

    let snap = ctx.telemetry_snapshot();
    let span = snap.spans.iter().find(|s| s.name == "solve.cg").expect("solve span");
    assert_ne!(span.trace, 0, "solves are traced");
    let iters =
        snap.events.iter().filter(|e| e.kind == "solver-iter" && e.trace == span.trace).count();
    assert_eq!(iters, rep.history.len(), "one solver-iter event per recorded residual");
    let done = snap
        .events
        .iter()
        .find(|e| e.kind == "solver-done" && e.trace == span.trace)
        .expect("solver-done");
    assert!(done.detail.contains("cg converged"), "{}", done.detail);
    // The solve story renders from the same ID space.
    let story = snap.describe_trace(span.trace);
    assert!(story.contains("solve.cg"), "{story}");
    assert!(story.contains("solver-done"), "{story}");
}
