//! Autotuner integration tests (ISSUE 3 acceptance criteria):
//!
//! 1. a tuned plan is only selected when its score is ≤ the default
//!    plan's (asserted per level),
//! 2. the plan cache round-trips byte-identically (save → load → build
//!    rebuilds the exact `EhybMatrix`),
//! 3. `TuneLevel::Measured` respects its time budget,
//!
//! plus the satellite property test that a tuned plan's SpMV results
//! match the default plan's on every engine: bit-identical wherever
//! tuning leaves the plan unchanged (all baseline kinds, and EHYB when
//! the default knobs win); when the tuner adopts a *different* EHYB
//! partitioning, per-row sums legitimately reassociate, so those cases
//! assert bit-identity against a direct rebuild of the tuned
//! configuration (tuning itself adds zero numerical deviation) plus
//! tight agreement with the default plan.

use ehyb::autotune::{config_key, device_key, tune, Fingerprint, PlanStore, TuneLevel, TunedPlan};
use ehyb::preprocess::{EhybPlan, PreprocessConfig};
use ehyb::sparse::coo::Coo;
use ehyb::sparse::csr::Csr;
use ehyb::sparse::ehyb::EhybMatrix;
use ehyb::sparse::gen::{poisson2d, unstructured_mesh};
use ehyb::util::check::{assert_allclose, check_prop};
use ehyb::util::Xoshiro256;
use ehyb::{EngineKind, SpmvContext};
use std::time::Duration;

fn random_matrix(rng: &mut Xoshiro256) -> Csr<f64> {
    let n = 16 + rng.next_below(300);
    let mut coo = Coo::new(n, n);
    for i in 0..n {
        if rng.next_f64() < 0.05 {
            continue; // empty row
        }
        coo.push(i, i, rng.range_f64(1.0, 4.0));
        let deg = rng.next_below(12);
        for _ in 0..deg {
            let j = if rng.next_f64() < 0.6 {
                let span = 24.min(n);
                (i + rng.next_below(span)).saturating_sub(span / 2).min(n - 1)
            } else {
                rng.next_below(n)
            };
            coo.push(i, j, rng.range_f64(-1.0, 1.0));
        }
    }
    coo.to_csr()
}

fn random_x(rng: &mut Xoshiro256, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.range_f64(-2.0, 2.0)).collect()
}

fn temp_dir(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("ehyb-autotune-test-{tag}-{}", std::process::id()))
}

/// "Byte-identical" for the plan-cache acceptance criterion: every
/// structural array equal AND every stored value equal at the bit
/// level (so even -0.0 vs 0.0 or NaN payloads would be caught).
fn assert_byte_identical(a: &EhybMatrix<f64>, b: &EhybMatrix<f64>) {
    assert_eq!(a, b, "structural/array mismatch");
    assert!(
        a.ell_vals.iter().zip(&b.ell_vals).all(|(x, y)| x.to_bits() == y.to_bits()),
        "ELL values differ at the bit level"
    );
    assert!(
        a.er_vals.iter().zip(&b.er_vals).all(|(x, y)| x.to_bits() == y.to_bits()),
        "ER values differ at the bit level"
    );
}

#[test]
fn prop_tuned_plan_matches_default_results_on_every_engine() {
    check_prop("tuned-matches-default", 0x7C11ED, 24, |rng| {
        let m = random_matrix(rng);
        let vec_size = 32 * (1 + rng.next_below(4));
        let cfg = PreprocessConfig { vec_size_override: Some(vec_size), ..Default::default() };
        let x = random_x(rng, m.ncols());
        for kind in EngineKind::ALL {
            let build = |tuned: bool, config: PreprocessConfig| {
                // no_plan_cache: keep the property independent of any
                // EHYB_TUNE_DIR set in the developer's environment.
                let mut b =
                    SpmvContext::builder(m.clone()).engine(kind).config(config).no_plan_cache();
                if tuned {
                    b = b.tune(TuneLevel::Heuristic);
                }
                b.build().map_err(|e| format!("{kind:?}: build: {e}"))
            };
            let ctx_d = build(false, cfg.clone())?;
            let ctx_t = build(true, cfg.clone())?;
            let y_d = ctx_d.spmv_alloc(&x).map_err(|e| e.to_string())?;
            let y_t = ctx_t.spmv_alloc(&x).map_err(|e| e.to_string())?;
            let plan_unchanged = ctx_t.config().vec_size_override == cfg.vec_size_override
                && ctx_t.config().slice_height == cfg.slice_height
                && ctx_t.config().ell_width_cutoff == cfg.ell_width_cutoff;
            if kind != EngineKind::Ehyb || plan_unchanged {
                // Identical plan => identical engine => bit-identical y.
                if y_t != y_d {
                    return Err(format!("{kind:?}: tuned != default bitwise"));
                }
            } else {
                // Different EHYB partitioning: same math, reassociated
                // sums. Tuning must add zero deviation beyond the plan
                // change itself: bit-identical to a direct rebuild of
                // the tuned configuration...
                let ctx_r = build(false, ctx_t.config().clone())?;
                let y_r = ctx_r.spmv_alloc(&x).map_err(|e| e.to_string())?;
                if y_t != y_r {
                    return Err("tuned != direct rebuild of tuned config (bitwise)".into());
                }
                // ...and numerically the same operator as the default.
                assert_allclose(&y_t, &y_d, 1e-9, 1e-9)
                    .map_err(|e| format!("tuned vs default: {e}"))?;
            }
            // Score guarantee holds on every tuned build.
            let tp = ctx_t.tuned().expect("tuned build carries plan");
            if tp.score_secs > tp.default_score_secs {
                return Err(format!(
                    "{kind:?}: tuned score {} > default {}",
                    tp.score_secs, tp.default_score_secs
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn tuned_score_never_worse_than_default_both_levels() {
    let matrices: Vec<(&str, Csr<f64>)> = vec![
        ("poisson", poisson2d::<f64>(24, 24)),
        ("mesh", unstructured_mesh::<f64>(32, 32, 0.4, 5)),
    ];
    let cfg = PreprocessConfig { vec_size_override: Some(128), ..Default::default() };
    for (name, m) in &matrices {
        for level in [TuneLevel::Heuristic, TuneLevel::measured()] {
            for requested in [EngineKind::Ehyb, EngineKind::Auto] {
                let out = tune(m, &cfg, requested, level).unwrap();
                assert!(
                    out.plan.score_secs <= out.plan.default_score_secs,
                    "{name}/{requested:?}/{level:?}: {} > {}",
                    out.plan.score_secs,
                    out.plan.default_score_secs
                );
            }
        }
    }
}

#[test]
fn measured_respects_time_budget() {
    let m = unstructured_mesh::<f64>(32, 32, 0.4, 7);
    let cfg = PreprocessConfig { vec_size_override: Some(64), ..Default::default() };
    // Zero budget: only the default plan may be probed.
    let out = tune(&m, &cfg, EngineKind::Auto, TuneLevel::Measured { budget: Duration::ZERO })
        .unwrap();
    assert_eq!(out.candidates_tried, 1, "zero budget must probe only the default");
    assert!(out.candidates_skipped > 0);
    assert_eq!(out.plan.score_secs, out.plan.default_score_secs);
    // Through the facade: a zero-budget tuned build degenerates to the
    // default plan (and stays correct).
    let ctx = SpmvContext::builder(m.clone())
        .engine(EngineKind::Ehyb)
        .config(cfg)
        .tune(TuneLevel::Measured { budget: Duration::ZERO })
        .no_plan_cache()
        .build()
        .unwrap();
    assert_eq!(ctx.config().vec_size_override, Some(64));
    let x: Vec<f64> = (0..m.nrows()).map(|i| ((i * 3 + 1) % 11) as f64 * 0.5 - 2.0).collect();
    assert_allclose(&ctx.spmv_alloc(&x).unwrap(), &m.spmv_f64_oracle(&x), 1e-10, 1e-10).unwrap();
}

#[test]
fn plan_cache_roundtrip_builds_byte_identical_matrix() {
    let dir = temp_dir("roundtrip");
    std::fs::remove_dir_all(&dir).ok();
    let m = unstructured_mesh::<f64>(32, 32, 0.4, 5);
    let cfg = PreprocessConfig { vec_size_override: Some(128), ..Default::default() };

    // Cold build: search + persist.
    let ctx1 = SpmvContext::builder(m.clone())
        .engine(EngineKind::Ehyb)
        .config(cfg.clone())
        .tune(TuneLevel::Heuristic)
        .plan_cache(&dir)
        .build()
        .unwrap();
    let tp = ctx1.tuned().unwrap().clone();

    // The store round-trips the TunedPlan identically...
    let store = PlanStore::new(&dir);
    let loaded = store.load(&tp.fingerprint, &tp.device, &tp.dtype, &tp.scope).unwrap().unwrap();
    assert_eq!(loaded, tp);

    // ...a warm build adopts it without re-searching (same plan object)...
    let ctx2 = SpmvContext::builder(m.clone())
        .engine(EngineKind::Ehyb)
        .config(cfg.clone())
        .tune(TuneLevel::Heuristic)
        .plan_cache(&dir)
        .build()
        .unwrap();
    assert_eq!(ctx2.tuned().unwrap(), &tp);

    // ...and both the warm context and a by-hand save→load→build
    // rebuild produce a byte-identical EhybMatrix.
    assert_byte_identical(&ctx1.plan().unwrap().matrix, &ctx2.plan().unwrap().matrix);
    let rebuilt = EhybPlan::build(&m, &loaded.apply(&cfg)).unwrap();
    assert_byte_identical(&ctx1.plan().unwrap().matrix, &rebuilt.matrix);

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn plan_cache_hit_bypasses_search() {
    let dir = temp_dir("hit");
    std::fs::remove_dir_all(&dir).ok();
    let m = poisson2d::<f64>(16, 16);
    let cfg = PreprocessConfig { vec_size_override: Some(64), ..Default::default() };
    // Plant a valid plan the tuner would never produce (sentinel scores,
    // "measured" tag on a heuristic request): if the build adopts it,
    // it came from the cache, not from a fresh search.
    let planted = TunedPlan {
        engine: EngineKind::Ehyb,
        slice_height: 32,
        vec_size: Some(96),
        ell_width_cutoff: None,
        score_secs: 1.0,
        default_score_secs: 1.0,
        level: "measured".into(),
        fingerprint: Fingerprint::of(&m).key(),
        device: device_key(&cfg.device),
        dtype: "f64".into(),
        base_config: config_key(&cfg),
        scope: "ehyb".into(),
        reorder: "none".into(),
        oracle: "roofline".into(),
        probe_width: 1,
    };
    PlanStore::new(&dir).save(&planted).unwrap();

    let ctx = SpmvContext::builder(m.clone())
        .engine(EngineKind::Ehyb)
        .config(cfg)
        .tune(TuneLevel::Heuristic)
        .plan_cache(&dir)
        .build()
        .unwrap();
    assert_eq!(ctx.tuned().unwrap(), &planted);
    assert_eq!(ctx.config().vec_size_override, Some(96));
    assert_eq!(ctx.plan().unwrap().matrix.vec_size, 96);
    // The cached plan still computes the right operator.
    let x: Vec<f64> = (0..256).map(|i| ((i * 7 + 3) % 13) as f64 * 0.5 - 3.0).collect();
    assert_allclose(&ctx.spmv_alloc(&x).unwrap(), &m.spmv_f64_oracle(&x), 1e-10, 1e-10).unwrap();

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cache_hit_never_overrides_explicit_engine_level_or_config() {
    let dir = temp_dir("compat");
    std::fs::remove_dir_all(&dir).ok();
    let m = poisson2d::<f64>(16, 16);
    let cfg = PreprocessConfig { vec_size_override: Some(64), ..Default::default() };
    // Plant a baseline-winner plan and deliberately file it under the
    // "ehyb" scope (a hand-copied / corrupted cache): even when the
    // scoped lookup finds it, usable_for must reject it for an
    // explicit EHYB request.
    let planted = TunedPlan {
        engine: EngineKind::CsrScalar,
        slice_height: 32,
        vec_size: Some(64),
        ell_width_cutoff: None,
        score_secs: 1.0,
        default_score_secs: 1.0,
        level: "heuristic".into(),
        fingerprint: Fingerprint::of(&m).key(),
        device: device_key(&cfg.device),
        dtype: "f64".into(),
        base_config: config_key(&cfg),
        scope: "ehyb".into(),
        reorder: "none".into(),
        oracle: "traffic".into(),
        probe_width: 0,
    };
    PlanStore::new(&dir).save(&planted).unwrap();

    // 1. Explicit EHYB request: the cached csr-scalar winner must not
    //    override it — the build re-tunes and yields an EHYB context
    //    (overwriting the entry with its own winner).
    let ctx = SpmvContext::builder(m.clone())
        .engine(EngineKind::Ehyb)
        .config(cfg.clone())
        .tune(TuneLevel::Heuristic)
        .plan_cache(&dir)
        .build()
        .unwrap();
    assert_eq!(ctx.kind(), EngineKind::Ehyb);
    assert!(ctx.plan().is_some());
    assert_eq!(ctx.tuned().unwrap().engine, EngineKind::Ehyb);

    // 2. Measured request: the (now heuristic, EHYB) entry must not
    //    serve it — a fresh measured search runs and persists. Budget
    //    generous enough to always compare candidates (a starved
    //    search would deliberately not persist).
    let measured = TuneLevel::Measured { budget: Duration::from_secs(10) };
    let ctx2 = SpmvContext::builder(m.clone())
        .engine(EngineKind::Ehyb)
        .config(cfg.clone())
        .tune(measured)
        .plan_cache(&dir)
        .build()
        .unwrap();
    assert_eq!(ctx2.tuned().unwrap().level, "measured");

    // 3. Heuristic request after that: the measured entry supersedes
    //    the heuristic model and is adopted as-is.
    let ctx3 = SpmvContext::builder(m.clone())
        .engine(EngineKind::Ehyb)
        .config(cfg.clone())
        .tune(TuneLevel::Heuristic)
        .plan_cache(&dir)
        .build()
        .unwrap();
    assert_eq!(ctx3.tuned().unwrap().level, "measured");
    assert_eq!(ctx3.tuned(), ctx2.tuned());

    // 4. A different base config (sort_descending off) must not reuse
    //    the entry: the plan it would rebuild is not the one scored.
    let cfg_off = PreprocessConfig { sort_descending: false, ..cfg.clone() };
    let ctx4 = SpmvContext::builder(m.clone())
        .engine(EngineKind::Ehyb)
        .config(cfg_off.clone())
        .tune(TuneLevel::Heuristic)
        .plan_cache(&dir)
        .build()
        .unwrap();
    assert_eq!(ctx4.tuned().unwrap().base_config, config_key(&cfg_off));
    assert_ne!(ctx4.tuned().unwrap().base_config, config_key(&cfg));

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn auto_with_measured_tuning_end_to_end() {
    let m = unstructured_mesh::<f64>(24, 24, 0.5, 3);
    let ctx = SpmvContext::builder(m.clone())
        .engine(EngineKind::Auto)
        .config(PreprocessConfig { vec_size_override: Some(96), ..Default::default() })
        .tune(TuneLevel::measured())
        .no_plan_cache()
        .build()
        .unwrap();
    assert_eq!(ctx.requested_kind(), EngineKind::Auto);
    assert_ne!(ctx.kind(), EngineKind::Auto);
    let tp = ctx.tuned().unwrap();
    assert_eq!(tp.level, "measured");
    assert!(tp.score_secs <= tp.default_score_secs);
    let x: Vec<f64> = (0..m.nrows()).map(|i| ((i * 5 + 2) % 17) as f64 * 0.25 - 2.0).collect();
    assert_allclose(&ctx.spmv_alloc(&x).unwrap(), &m.spmv_f64_oracle(&x), 1e-9, 1e-9).unwrap();
}
