//! Sharding gate (ISSUE 4 acceptance): the row-sharded engine against
//! the unsharded one, for every `EngineKind` and K ∈ {1, 2, 7,
//! num_threads}, plus the per-shard plan-cache round-trip and the
//! solver/service layers running unchanged on a sharded context.
//!
//! Numerical contract under test (see `ehyb::shard` docs): for every
//! engine whose per-row accumulation depends only on that row's entries
//! — csr-scalar, csr-vector, ell, hyb, sellp, csr5 — sharded output is
//! **bitwise identical** to the unsharded engine at every K. The two
//! engines that re-derive a global data-dependent layout (`merge`'s
//! team grid, `ehyb`'s per-shard repartitioning) are bitwise identical
//! at K = 1, bitwise deterministic at every K, and match the unsharded
//! engine to roundoff.

use ehyb::preprocess::PreprocessConfig;
use ehyb::sparse::coo::Coo;
use ehyb::sparse::csr::Csr;
use ehyb::util::check::{assert_allclose, check_prop, default_cases};
use ehyb::util::{par, Xoshiro256};
use ehyb::{BatchBuf, EngineKind, ShardSpec, ShardStrategy, SpmvContext, TuneLevel};

/// Engines whose sharded execution must be bit-identical to the
/// unsharded engine at every K (row-local per-row accumulation).
const ROW_LOCAL: [EngineKind; 6] = [
    EngineKind::CsrScalar,
    EngineKind::CsrVector,
    EngineKind::Ell,
    EngineKind::Hyb,
    EngineKind::SellP,
    EngineKind::Csr5,
];

/// Engines that re-derive a global layout per shard: bitwise at K = 1,
/// deterministic + allclose at K > 1.
const GLOBAL_LAYOUT: [EngineKind; 2] = [EngineKind::Ehyb, EngineKind::Merge];

fn shard_counts() -> Vec<usize> {
    let mut ks = vec![1usize, 2, 7];
    let t = par::num_threads();
    if !ks.contains(&t) {
        ks.push(t);
    }
    ks
}

fn random_matrix(rng: &mut Xoshiro256) -> Csr<f64> {
    let n = 32 + rng.next_below(300);
    let mut coo = Coo::new(n, n);
    for i in 0..n {
        coo.push(i, i, rng.range_f64(1.0, 4.0));
        let deg = rng.next_below(10);
        for _ in 0..deg {
            let j = if rng.next_f64() < 0.6 {
                let span = 24.min(n);
                (i + rng.next_below(span)).saturating_sub(span / 2).min(n - 1)
            } else {
                rng.next_below(n)
            };
            coo.push(i, j, rng.range_f64(-1.0, 1.0));
        }
    }
    coo.to_csr()
}

fn random_x(rng: &mut Xoshiro256, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.range_f64(-2.0, 2.0)).collect()
}

fn cfg(vec_size: usize) -> PreprocessConfig {
    PreprocessConfig { vec_size_override: Some(vec_size), ..Default::default() }
}

fn sharded_ctx(
    m: &Csr<f64>,
    kind: EngineKind,
    k: usize,
    strategy: ShardStrategy,
    vec_size: usize,
) -> SpmvContext<f64> {
    SpmvContext::builder(m.clone())
        .engine(kind)
        .config(cfg(vec_size))
        .shards(ShardSpec::Count(k))
        .shard_strategy(strategy)
        .build()
        .unwrap_or_else(|e| panic!("{kind:?} k={k}: build failed: {e:#}"))
}

#[test]
fn prop_sharded_bitwise_identical_on_row_local_engines() {
    check_prop("sharded-bitwise-row-local", 0x54A8D1, default_cases(), |rng| {
        let m = random_matrix(rng);
        let vec_size = 32 * (1 + rng.next_below(3));
        let x = random_x(rng, m.ncols());
        for kind in ROW_LOCAL {
            let base = SpmvContext::builder(m.clone())
                .engine(kind)
                .config(cfg(vec_size))
                .build()
                .map_err(|e| format!("{kind:?}: unsharded build: {e:#}"))?;
            let y_ref = base.spmv_alloc(&x).map_err(|e| e.to_string())?;
            for strategy in [ShardStrategy::NnzBalanced, ShardStrategy::CacheAware] {
                for &k in &shard_counts() {
                    let ctx = sharded_ctx(&m, kind, k, strategy, vec_size);
                    let y = ctx.spmv_alloc(&x).map_err(|e| e.to_string())?;
                    if y != y_ref {
                        return Err(format!(
                            "{kind:?} k={k} {strategy:?}: sharded != unsharded bitwise \
                             (n={}, shards={})",
                            m.nrows(),
                            ctx.shards()
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sharded_global_layout_engines_k1_bitwise_all_k_allclose() {
    check_prop("sharded-global-layout", 0x54A8D2, default_cases(), |rng| {
        let m = random_matrix(rng);
        let vec_size = 32 * (1 + rng.next_below(3));
        let x = random_x(rng, m.ncols());
        for kind in GLOBAL_LAYOUT {
            let base = SpmvContext::builder(m.clone())
                .engine(kind)
                .config(cfg(vec_size))
                .build()
                .map_err(|e| format!("{kind:?}: unsharded build: {e:#}"))?;
            let y_ref = base.spmv_alloc(&x).map_err(|e| e.to_string())?;
            // K = 1: one shard IS the whole matrix — the same layout is
            // derived, so even these engines must match bitwise.
            let one = sharded_ctx(&m, kind, 1, ShardStrategy::CacheAware, vec_size);
            let y1 = one.spmv_alloc(&x).map_err(|e| e.to_string())?;
            if y1 != y_ref {
                return Err(format!("{kind:?} k=1: sharded != unsharded bitwise"));
            }
            for &k in &shard_counts() {
                let ctx = sharded_ctx(&m, kind, k, ShardStrategy::CacheAware, vec_size);
                let y = ctx.spmv_alloc(&x).map_err(|e| e.to_string())?;
                assert_allclose(&y, &y_ref, 1e-9, 1e-9)
                    .map_err(|e| format!("{kind:?} k={k}: {e}"))?;
                // Re-deriving the shard layouts is deterministic.
                let again = sharded_ctx(&m, kind, k, ShardStrategy::CacheAware, vec_size);
                let y2 = again.spmv_alloc(&x).map_err(|e| e.to_string())?;
                if y != y2 {
                    return Err(format!("{kind:?} k={k}: sharded build not deterministic"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sharded_batch_bitwise_matches_repeated_sharded_spmv() {
    check_prop("sharded-batch-equals-repeated", 0x54A8D3, default_cases(), |rng| {
        let m = random_matrix(rng);
        let vec_size = 32 * (1 + rng.next_below(3));
        let bw = 1 + rng.next_below(5);
        let xs: Vec<Vec<f64>> = (0..bw).map(|_| random_x(rng, m.ncols())).collect();
        let xrefs: Vec<&[f64]> = xs.iter().map(|v| v.as_slice()).collect();
        let xbatch = BatchBuf::from_cols(&xrefs).map_err(|e| e.to_string())?;
        let k = 2 + rng.next_below(6);
        for kind in ROW_LOCAL.iter().chain(GLOBAL_LAYOUT.iter()) {
            let ctx = sharded_ctx(&m, *kind, k, ShardStrategy::CacheAware, vec_size);
            let mut ys = BatchBuf::<f64>::zeros(m.nrows(), bw);
            {
                let mut yv = ys.view_mut();
                ctx.spmv_batch(xbatch.view(), &mut yv).map_err(|e| e.to_string())?;
            }
            for (b, x) in xs.iter().enumerate() {
                let y1 = ctx.spmv_alloc(x).map_err(|e| e.to_string())?;
                if y1[..] != *ys.col(b) {
                    return Err(format!("{kind:?} k={k}: batch lane {b} != sharded spmv"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn per_shard_plans_persist_and_reload_through_the_store() {
    // Sharded EHYB + tune + plan cache: each shard persists its own
    // entry keyed by its diagonal block's fingerprint, and a rebuild
    // pointing at the same cache warm-starts every shard with the
    // identical plan (bitwise-identical execution).
    let m = ehyb::sparse::gen::unstructured_mesh::<f64>(40, 40, 0.4, 17);
    let dir = std::env::temp_dir().join(format!("ehyb-shard-store-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let k = 4;
    let build = || {
        SpmvContext::builder(m.clone())
            .engine(EngineKind::Ehyb)
            .config(cfg(64))
            .tune(TuneLevel::Heuristic)
            .plan_cache(&dir)
            .shards(ShardSpec::Count(k))
            .build()
            .unwrap()
    };
    let cold = build();
    assert_eq!(cold.tuned_shards().len(), k);
    let cold_plans: Vec<_> = cold.tuned_shards().to_vec();
    for tp in cold_plans.iter() {
        let tp = tp.as_ref().expect("mesh shards have diagonal entries");
        assert_eq!(tp.scope, "ehyb");
        assert!(tp.score_secs <= tp.default_score_secs);
    }
    // One cache file per shard fingerprint (all distinct blocks), plus
    // the whole-matrix entry the builder's own tuning arm persists.
    let entries: Vec<_> = std::fs::read_dir(&dir)
        .expect("cache dir created")
        .filter_map(|e| e.ok())
        .filter(|e| e.path().extension().is_some_and(|x| x == "json"))
        .collect();
    assert_eq!(entries.len(), k + 1, "one persisted plan per shard + the whole-matrix plan");
    // Warm rebuild: same plans come back from the store...
    let warm = build();
    assert_eq!(warm.tuned_shards(), &cold_plans[..]);
    // ...and execution is bitwise identical between cold and warm.
    let x: Vec<f64> = (0..m.ncols()).map(|i| ((i * 13 + 3) % 23) as f64 * 0.25 - 2.5).collect();
    assert_eq!(cold.spmv_alloc(&x).unwrap(), warm.spmv_alloc(&x).unwrap());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cg_runs_unchanged_on_sharded_engine() {
    // The solver layer is engine-agnostic: on a row-local engine kind,
    // CG over the sharded context follows the exact same trajectory
    // (bitwise) as over the unsharded one.
    let m = ehyb::sparse::gen::poisson2d::<f64>(24, 24);
    let n = m.nrows();
    let b: Vec<f64> = (0..n).map(|i| ((i * 7 + 5) % 17) as f64 / 17.0 - 0.5).collect();
    let pre = ehyb::coordinator::Jacobi::new(&m);
    let scfg = ehyb::coordinator::SolverConfig::default();
    let base = SpmvContext::builder(m.clone()).engine(EngineKind::CsrScalar).build().unwrap();
    let (x_ref, rep_ref) = base.solver().cg(&b, None, &pre, &scfg).unwrap();
    assert!(rep_ref.converged());
    let ctx = sharded_ctx(&m, EngineKind::CsrScalar, 5, ShardStrategy::CacheAware, 64);
    let (x, rep) = ctx.solver().cg(&b, None, &pre, &scfg).unwrap();
    assert!(rep.converged());
    assert_eq!(rep.iters, rep_ref.iters);
    assert_eq!(x, x_ref, "sharded CG trajectory must be bitwise identical");
    // And the sharded EHYB engine still solves (roundoff-equivalent).
    let ehyb_ctx = sharded_ctx(&m, EngineKind::Ehyb, 3, ShardStrategy::CacheAware, 64);
    let (xe, repe) = ehyb_ctx.solver().cg(&b, None, &pre, &scfg).unwrap();
    assert!(repe.converged());
    let mut ax = vec![0.0; n];
    m.spmv(&xe, &mut ax);
    assert_allclose(&ax, &b, 1e-6, 1e-6).unwrap();
}

#[test]
fn cg_many_fuses_on_sharded_engine() {
    let m = ehyb::sparse::gen::poisson2d::<f64>(20, 20);
    let n = m.nrows();
    let bs: Vec<Vec<f64>> = (0..3)
        .map(|t| (0..n).map(|i| ((i * 3 + t * 11 + 1) % 19) as f64 / 19.0 - 0.5).collect())
        .collect();
    let pre = ehyb::coordinator::Jacobi::new(&m);
    let scfg = ehyb::coordinator::SolverConfig::default();
    let ctx = sharded_ctx(&m, EngineKind::Ehyb, 4, ShardStrategy::CacheAware, 64);
    let sols = ctx.solver().cg_many(&bs, &pre, &scfg).unwrap();
    assert_eq!(sols.len(), 3);
    for (b, (x, rep)) in bs.iter().zip(&sols) {
        assert!(rep.converged(), "{rep:?}");
        let mut ax = vec![0.0; n];
        m.spmv(x, &mut ax);
        assert_allclose(&ax, b, 1e-6, 1e-6).unwrap();
    }
    // The sharded engine saw fused batches: every shard's lane counter
    // advanced by the batch width per iteration.
    let stats = ctx.sharded().unwrap().stats();
    assert!(stats.iter().all(|s| s.lanes.load(std::sync::atomic::Ordering::Relaxed) > 0));
}

#[test]
fn service_drains_one_fused_batch_per_shard() {
    let m = ehyb::sparse::gen::poisson2d::<f64>(16, 16);
    let ctx = sharded_ctx(&m, EngineKind::Ehyb, 4, ShardStrategy::CacheAware, 64);
    let svc = ctx.serve(8).unwrap();
    let client = svc.client();
    let xs: Vec<Vec<f64>> = (0..6)
        .map(|t| (0..256).map(|i| ((i * 5 + t * 7) % 11) as f64 * 0.5 - 2.0).collect())
        .collect();
    let ys = client.spmv_many(xs.clone()).unwrap();
    for (x, y) in xs.iter().zip(&ys) {
        let mut want = vec![0.0; 256];
        m.spmv(x, &mut want);
        assert_allclose(y, &want, 1e-10, 1e-10).unwrap();
    }
    drop(svc);
    // Each service drain ran exactly one fused batch per shard: shard
    // batch counters equal the service's fused-batch count (plus the
    // single-vector path count staying zero).
    let batches: Vec<u64> = ctx
        .sharded()
        .unwrap()
        .stats()
        .iter()
        .map(|s| s.batch_calls.load(std::sync::atomic::Ordering::Relaxed))
        .collect();
    assert!(batches.iter().all(|&b| b == batches[0] && b > 0), "{batches:?}");
}

#[test]
fn auto_resolution_composes_with_sharding() {
    // Auto resolves the kind on the whole matrix, then the winner is
    // sharded; the context reports both the resolution and the shards.
    let m = ehyb::sparse::gen::unstructured_mesh::<f64>(48, 48, 0.3, 1);
    let ctx = SpmvContext::builder(m.clone())
        .engine(EngineKind::Auto)
        .config(cfg(512))
        .shards(ShardSpec::Count(3))
        .build()
        .unwrap();
    assert_eq!(ctx.requested_kind(), EngineKind::Auto);
    assert_ne!(ctx.kind(), EngineKind::Auto);
    assert_eq!(ctx.shards(), 3);
    let x = vec![1.0; m.ncols()];
    let y = ctx.spmv_alloc(&x).unwrap();
    assert_allclose(&y, &m.spmv_f64_oracle(&x), 1e-9, 1e-9).unwrap();
}
