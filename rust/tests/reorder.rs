//! Reordering gate (ISSUE 5 acceptance): every `ReorderSpec` against
//! every engine kind through the facade, the RCM permutation contract
//! on disconnected graphs, reorder × shards × tune composition, the
//! bandwidth/cut acceptance criterion on banded/FEM-like generators,
//! and the pooled-scratch steady-state invariant.
//!
//! Numerical contract under test (see `ehyb::reorder` docs): the
//! permuted matrix preserves each row's entry order
//! (`Csr::permute_symmetric_stable`) and the adapter permutes `x` in /
//! `y` out — so for every row-local engine kind (csr-scalar,
//! csr-vector, ell, hyb, sellp, csr5) the reordered result is
//! **bitwise identical** to the unsharded, unreordered engine. The two
//! global-layout engines (`ehyb`, `merge`) re-derive their layouts on
//! the permuted structure (that is the point — the partitioner sees
//! the improved locality) and agree to 1e-9.

use ehyb::preprocess::{EhybPlan, PreprocessConfig};
use ehyb::reorder::ReorderedEngine;
use ehyb::shard::{ShardPlan, ShardStrategy};
use ehyb::spmv::ehyb_cpu::EhybCpu;
use ehyb::spmv::SpmvEngine;
use std::sync::Arc;
use ehyb::sparse::coo::Coo;
use ehyb::sparse::csr::Csr;
use ehyb::sparse::gen::{banded, unstructured_mesh};
use ehyb::util::check::{assert_allclose, check_prop, default_cases};
use ehyb::util::Xoshiro256;
use ehyb::{
    BatchBuf, EngineKind, ReorderSpec, Reordering, ShardSpec, SpmvContext, TuneLevel,
};

const ROW_LOCAL: [EngineKind; 6] = [
    EngineKind::CsrScalar,
    EngineKind::CsrVector,
    EngineKind::Ell,
    EngineKind::Hyb,
    EngineKind::SellP,
    EngineKind::Csr5,
];

const GLOBAL_LAYOUT: [EngineKind; 2] = [EngineKind::Ehyb, EngineKind::Merge];

const SPECS: [ReorderSpec; 5] = [
    ReorderSpec::None,
    ReorderSpec::DegreeSort,
    ReorderSpec::Rcm,
    ReorderSpec::PartitionRank { k: 0 },
    ReorderSpec::Auto,
];

fn cfg(vec_size: usize) -> PreprocessConfig {
    PreprocessConfig { vec_size_override: Some(vec_size), ..Default::default() }
}

fn random_matrix(rng: &mut Xoshiro256) -> Csr<f64> {
    let n = 32 + rng.next_below(220);
    let mut coo = Coo::new(n, n);
    for i in 0..n {
        coo.push(i, i, rng.range_f64(1.0, 4.0));
        let deg = rng.next_below(9);
        for _ in 0..deg {
            let j = if rng.next_f64() < 0.6 {
                let span = 24.min(n);
                (i + rng.next_below(span)).saturating_sub(span / 2).min(n - 1)
            } else {
                rng.next_below(n)
            };
            coo.push(i, j, rng.range_f64(-1.0, 1.0));
        }
    }
    coo.to_csr()
}

fn random_x(rng: &mut Xoshiro256, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.range_f64(-2.0, 2.0)).collect()
}

/// A banded matrix hidden behind a random relabeling.
fn scrambled_banded(n: usize, bw: usize, seed: u64) -> Csr<f64> {
    let m = banded::<f64>(n, bw, 0.7, seed);
    let mut shuffle: Vec<u32> = (0..n as u32).collect();
    Xoshiro256::new(seed ^ 0xD1CE).shuffle(&mut shuffle);
    m.permute_symmetric_stable(&shuffle)
}

#[test]
fn prop_every_spec_roundtrips_exactly_on_every_engine() {
    check_prop("reorder-roundtrip", 0x5E08D1, default_cases(), |rng| {
        let m = random_matrix(rng);
        let vec_size = 32 * (1 + rng.next_below(3));
        let x = random_x(rng, m.ncols());
        // One spec per case keeps the sweep tractable; the seed walk
        // covers all of them many times over.
        let spec = SPECS[rng.next_below(SPECS.len())];
        for kind in ROW_LOCAL {
            let base = SpmvContext::builder(m.clone())
                .engine(kind)
                .config(cfg(vec_size))
                .build()
                .map_err(|e| format!("{kind:?}: base build: {e:#}"))?;
            let y_ref = base.spmv_alloc(&x).map_err(|e| e.to_string())?;
            let ctx = SpmvContext::builder(m.clone())
                .engine(kind)
                .config(cfg(vec_size))
                .reorder(spec)
                .build()
                .map_err(|e| format!("{kind:?} {spec:?}: build: {e:#}"))?;
            let y = ctx.spmv_alloc(&x).map_err(|e| e.to_string())?;
            if y != y_ref {
                return Err(format!(
                    "{kind:?} {spec:?}: reordered != plain bitwise (n={}, resolved={:?})",
                    m.nrows(),
                    ctx.reordering().map(|r| r.resolved.clone())
                ));
            }
        }
        for kind in GLOBAL_LAYOUT {
            let base = SpmvContext::builder(m.clone())
                .engine(kind)
                .config(cfg(vec_size))
                .build()
                .map_err(|e| format!("{kind:?}: base build: {e:#}"))?;
            let y_ref = base.spmv_alloc(&x).map_err(|e| e.to_string())?;
            let ctx = SpmvContext::builder(m.clone())
                .engine(kind)
                .config(cfg(vec_size))
                .reorder(spec)
                .build()
                .map_err(|e| format!("{kind:?} {spec:?}: build: {e:#}"))?;
            let y = ctx.spmv_alloc(&x).map_err(|e| e.to_string())?;
            assert_allclose(&y, &y_ref, 1e-9, 1e-9)
                .map_err(|e| format!("{kind:?} {spec:?}: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_reordered_batch_bitwise_matches_repeated_spmv() {
    check_prop("reorder-batch-equals-repeated", 0x5E08D2, default_cases(), |rng| {
        let m = random_matrix(rng);
        let vec_size = 32 * (1 + rng.next_below(3));
        let bw = 1 + rng.next_below(5);
        let xs: Vec<Vec<f64>> = (0..bw).map(|_| random_x(rng, m.ncols())).collect();
        let xrefs: Vec<&[f64]> = xs.iter().map(|v| v.as_slice()).collect();
        let xbatch = BatchBuf::from_cols(&xrefs).map_err(|e| e.to_string())?;
        let spec = SPECS[rng.next_below(SPECS.len())];
        for kind in [EngineKind::CsrScalar, EngineKind::Ehyb, EngineKind::SellP] {
            let ctx = SpmvContext::builder(m.clone())
                .engine(kind)
                .config(cfg(vec_size))
                .reorder(spec)
                .build()
                .map_err(|e| format!("{kind:?} {spec:?}: build: {e:#}"))?;
            let mut ys = BatchBuf::<f64>::zeros(m.nrows(), bw);
            {
                let mut yv = ys.view_mut();
                ctx.spmv_batch(xbatch.view(), &mut yv).map_err(|e| e.to_string())?;
            }
            for (b, x) in xs.iter().enumerate() {
                let y1 = ctx.spmv_alloc(x).map_err(|e| e.to_string())?;
                if y1[..] != *ys.col(b) {
                    return Err(format!("{kind:?} {spec:?}: batch lane {b} != spmv"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_rcm_is_a_bijection_on_disconnected_graphs() {
    check_prop("rcm-bijection-disconnected", 0x5E08D3, default_cases(), |rng| {
        // Random block-diagonal structure: several disjoint chains or
        // cliques plus isolated diagonal-only rows — RCM must visit
        // every component and still emit a bijection.
        let blocks = 1 + rng.next_below(5);
        let isolated = rng.next_below(8);
        let mut sizes: Vec<usize> = (0..blocks).map(|_| 2 + rng.next_below(24)).collect();
        sizes.push(isolated);
        let n: usize = sizes.iter().sum();
        let mut coo = Coo::<f64>::new(n, n);
        for i in 0..n {
            coo.push(i, i, 2.0 + rng.next_f64());
        }
        let mut base = 0usize;
        for &sz in &sizes[..blocks] {
            for i in 0..sz {
                // chain within the block, occasional extra edge
                if i + 1 < sz {
                    coo.push(base + i, base + i + 1, -1.0);
                    coo.push(base + i + 1, base + i, -1.0);
                }
                if sz > 3 && rng.next_f64() < 0.3 {
                    let j = rng.next_below(sz);
                    coo.push(base + i, base + j, -0.5);
                }
            }
            base += sz;
        }
        let m = coo.to_csr();
        let r = Reordering::compute(&m, ReorderSpec::Rcm).map_err(|e| e.to_string())?;
        let mut seen = vec![false; n];
        for &p in &r.perm {
            if p as usize >= n || seen[p as usize] {
                return Err(format!("perm not a bijection at target {p} (n={n})"));
            }
            seen[p as usize] = true;
        }
        // And the permuted pipeline still computes the same operator.
        let x = random_x(rng, n);
        let base_ctx = SpmvContext::builder(m.clone())
            .engine(EngineKind::CsrScalar)
            .build()
            .map_err(|e| e.to_string())?;
        let ctx = SpmvContext::builder(m)
            .engine(EngineKind::CsrScalar)
            .reorder(ReorderSpec::Rcm)
            .build()
            .map_err(|e| e.to_string())?;
        if ctx.spmv_alloc(&x).map_err(|e| e.to_string())?
            != base_ctx.spmv_alloc(&x).map_err(|e| e.to_string())?
        {
            return Err("rcm round-trip not bitwise on disconnected graph".into());
        }
        Ok(())
    });
}

#[test]
fn reorder_shards_tune_compose_without_double_permuting() {
    let m = unstructured_mesh::<f64>(32, 32, 0.4, 5);
    let x: Vec<f64> = (0..m.ncols()).map(|i| ((i * 7 + 3) % 19) as f64 * 0.25 - 2.0).collect();
    let oracle = m.spmv_f64_oracle(&x);
    // Row-local kind: reorder × shards must still be bitwise equal to
    // the plain engine — any double permutation (adapter + a second
    // permute somewhere downstream) would scramble the result.
    let plain = SpmvContext::builder(m.clone()).engine(EngineKind::CsrScalar).build().unwrap();
    let y_ref = plain.spmv_alloc(&x).unwrap();
    for k in [1usize, 3] {
        let ctx = SpmvContext::builder(m.clone())
            .engine(EngineKind::CsrScalar)
            .reorder(ReorderSpec::Rcm)
            .shards(ShardSpec::Count(k))
            .build()
            .unwrap();
        assert_eq!(ctx.spmv_alloc(&x).unwrap(), y_ref, "k={k}");
        assert_eq!(ctx.shards(), k);
    }
    // Full stack: reorder × shards × tune on EHYB, still the same
    // operator (1e-9; shards re-derive layouts) and the tuned plans
    // carry the reorder provenance.
    let ctx = SpmvContext::builder(m.clone())
        .engine(EngineKind::Ehyb)
        .config(cfg(64))
        .reorder(ReorderSpec::Rcm)
        .shards(ShardSpec::Count(3))
        .tune(TuneLevel::Heuristic)
        .no_plan_cache()
        .build()
        .unwrap();
    assert_allclose(&ctx.spmv_alloc(&x).unwrap(), &oracle, 1e-9, 1e-9).unwrap();
    let r = ctx.reordering().expect("reordered build");
    assert_eq!(r.resolved, "rcm");
    assert_eq!(ctx.tuned().unwrap().reorder, "rcm");
    for tp in ctx.tuned_shards().iter().flatten() {
        assert_eq!(tp.reorder, "rcm", "per-shard plans record the ordering");
    }
    // The solver runs unchanged on a reordered context (bitwise CG
    // trajectory on the row-local kind).
    let b: Vec<f64> = (0..m.nrows()).map(|i| ((i * 11 + 5) % 23) as f64 / 23.0 - 0.5).collect();
    let pre = ehyb::coordinator::Jacobi::new(&m);
    let scfg = ehyb::coordinator::SolverConfig::default();
    let reordered = SpmvContext::builder(m.clone())
        .engine(EngineKind::CsrScalar)
        .reorder(ReorderSpec::Rcm)
        .build()
        .unwrap();
    let (sol_ref, rep_ref) = plain.solver().cg(&b, None, &pre, &scfg).unwrap();
    let (sol, rep) = reordered.solver().cg(&b, None, &pre, &scfg).unwrap();
    assert!(rep.converged() && rep_ref.converged());
    assert_eq!(sol, sol_ref, "CG trajectory must be bitwise identical under reordering");
}

/// ISSUE 9 acceptance: the 0.9 gather-fused adapter route is the same
/// operator, bit for bit, as the 0.8 two-pass permute route — across
/// reorder specs, through the facade, and composed with tune/shards.
#[test]
fn fused_gather_bitwise_equals_two_pass_on_compositions() {
    let m = unstructured_mesh::<f64>(22, 24, 0.6, 19);
    let n = m.nrows();
    let x: Vec<f64> = (0..n).map(|i| ((i * 5 + 1) % 29) as f64 * 0.125 - 1.5).collect();
    for spec in [ReorderSpec::DegreeSort, ReorderSpec::Rcm, ReorderSpec::PartitionRank { k: 0 }] {
        let ctx = SpmvContext::builder(m.clone())
            .engine(EngineKind::Ehyb)
            .config(cfg(64))
            .reorder(spec)
            .no_plan_cache()
            .build()
            .unwrap();
        // Rebuild the exact same reordering + plan and run it through
        // the explicit two-pass (0.8.0) route.
        let r = Arc::new(ctx.reordering().expect("reordering").clone());
        let pm = ctx.reordered_matrix().expect("reordered matrix").clone();
        let plan = EhybPlan::build(&pm, &cfg(64)).unwrap();
        let inner: Arc<dyn SpmvEngine<f64>> = Arc::new(EhybCpu::new(&plan));
        let fused = ReorderedEngine::new(inner.clone(), r.clone());
        let two = ReorderedEngine::with_fusion(inner, r, false);
        assert!(fused.is_fused(), "EHYB inner must fuse under {spec:?}");
        assert!(!two.is_fused());
        let mut y_f = vec![0.0; n];
        let mut y_two = vec![0.0; n];
        fused.spmv(&x, &mut y_f);
        two.spmv(&x, &mut y_two);
        assert_eq!(y_f, y_two, "fused != two-pass under {spec:?}");
        // The facade's automatically-fused engine is that operator too.
        assert_eq!(ctx.spmv_alloc(&x).unwrap(), y_two, "facade route under {spec:?}");
        // Batch path: fused single-gather batch vs two-pass blocked SpMM.
        let mut xs = BatchBuf::<f64>::zeros(n, 3);
        for b in 0..3 {
            for i in 0..n {
                xs.col_mut(b)[i] = ((i * 3 + b * 13 + 2) % 17) as f64 * 0.25 - 2.0;
            }
        }
        let mut ys_f = BatchBuf::<f64>::zeros(n, 3);
        let mut ys_t = BatchBuf::<f64>::zeros(n, 3);
        {
            let mut v = ys_f.view_mut();
            fused.spmv_batch(xs.view(), &mut v);
        }
        {
            let mut v = ys_t.view_mut();
            two.spmv_batch(xs.view(), &mut v);
        }
        for b in 0..3 {
            assert_eq!(ys_f.col(b), ys_t.col(b), "batch lane {b} under {spec:?}");
        }
        // × tune: the tuned facade may adopt a different plan, so the
        // contract is operator equality (1e-9), not bitwise.
        let tuned = SpmvContext::builder(m.clone())
            .engine(EngineKind::Ehyb)
            .config(cfg(64))
            .reorder(spec)
            .tune(TuneLevel::Heuristic)
            .no_plan_cache()
            .build()
            .unwrap();
        assert_allclose(&tuned.spmv_alloc(&x).unwrap(), &y_two, 1e-9, 1e-9).unwrap();
        // × shards: ShardedEngine exposes no permuted kernel, so fusion
        // disengages inside the shards; the composition must stay the
        // same operator.
        let sharded = SpmvContext::builder(m.clone())
            .engine(EngineKind::Ehyb)
            .config(cfg(64))
            .reorder(spec)
            .shards(ShardSpec::Count(3))
            .no_plan_cache()
            .build()
            .unwrap();
        assert_allclose(&sharded.spmv_alloc(&x).unwrap(), &y_two, 1e-9, 1e-9).unwrap();
    }
}

#[test]
fn acceptance_rcm_and_partrank_reduce_bandwidth_and_cache_aware_cut() {
    // ISSUE 5 acceptance: on the banded (scrambled) and FEM-like
    // generator matrices, Rcm and PartitionRank each reduce the
    // measured bandwidth AND the CacheAware cut_nnz versus None.
    let k = 8;
    for (name, m) in [
        ("scrambled-banded", scrambled_banded(2000, 8, 3)),
        ("unstructured-mesh", unstructured_mesh::<f64>(40, 40, 0.4, 7)),
    ] {
        let none = Reordering::compute(&m, ReorderSpec::None).unwrap();
        let cut_none = ShardPlan::new(&m, k, ShardStrategy::CacheAware).cut_nnz(&m);
        for spec in [ReorderSpec::Rcm, ReorderSpec::PartitionRank { k: 0 }] {
            let r = Reordering::compute(&m, spec).unwrap();
            assert!(
                r.after.bandwidth < none.after.bandwidth,
                "{name} {spec:?}: bandwidth {} !< {}",
                r.after.bandwidth,
                none.after.bandwidth
            );
            let pm = r.apply(&m);
            let cut = ShardPlan::new(&pm, k, ShardStrategy::CacheAware).cut_nnz(&pm);
            assert!(
                cut < cut_none,
                "{name} {spec:?}: cache-aware cut {cut} !< natural {cut_none}"
            );
            // The facade reports the same before/after pair.
            let ctx = SpmvContext::builder(m.clone())
                .engine(EngineKind::CsrScalar)
                .reorder(spec)
                .shards(ShardSpec::Count(k))
                .build()
                .unwrap();
            let (before, after) = ctx.reorder_cut_nnz().expect("reorder × shards");
            assert_eq!(before, cut_none, "{name} {spec:?}");
            assert_eq!(after, cut, "{name} {spec:?}");
            assert!(after < before, "{name} {spec:?}");
        }
    }
}

#[test]
fn sharded_batch_scratch_stays_allocation_free_in_steady_state() {
    // ISSUE 5 satellite through the public facade: repeated fused
    // batches on a sharded context must stop allocating after warm-up
    // (ShardedEngine staging pools + EhybShard x-staging pools).
    let m = unstructured_mesh::<f64>(24, 24, 0.4, 9);
    for kind in [EngineKind::Ehyb, EngineKind::CsrScalar] {
        let ctx = SpmvContext::builder(m.clone())
            .engine(kind)
            .config(cfg(64))
            .shards(ShardSpec::Count(3))
            .build()
            .unwrap();
        let width = 4;
        let mut xs = BatchBuf::<f64>::zeros(m.ncols(), width);
        for b in 0..width {
            for i in 0..m.ncols() {
                xs.col_mut(b)[i] = ((i * 3 + b * 7 + 1) % 13) as f64 * 0.5 - 3.0;
            }
        }
        let mut ys = BatchBuf::<f64>::zeros(m.nrows(), width);
        {
            let mut yv = ys.view_mut();
            ctx.spmv_batch(xs.view(), &mut yv).unwrap();
        }
        let sharded = ctx.sharded().unwrap();
        let after_first = sharded.scratch_misses();
        assert!(after_first > 0, "{kind:?}: first call populates the pools");
        for _ in 0..10 {
            let mut yv = ys.view_mut();
            ctx.spmv_batch(xs.view(), &mut yv).unwrap();
        }
        assert_eq!(
            sharded.scratch_misses(),
            after_first,
            "{kind:?}: steady-state batches must not allocate"
        );
    }
}

#[test]
fn sharded_untuned_ehyb_runs_k_block_pipelines_not_k_plus_one() {
    // ISSUE 5 satellite: at K >= 2 the whole-matrix EhybPlan is never
    // executed, so it must not be built — the per-shard preprocessing
    // timings are the proof (K pipelines ran, and ctx.plan() carries
    // no K+1-th).
    let m = unstructured_mesh::<f64>(24, 24, 0.4, 3);
    let ctx = SpmvContext::builder(m.clone())
        .engine(EngineKind::Ehyb)
        .config(cfg(64))
        .shards(ShardSpec::Count(4))
        .build()
        .unwrap();
    assert!(ctx.plan().is_none(), "whole-matrix plan must be skipped at K >= 2");
    let preps: Vec<_> =
        ctx.sharded().unwrap().stats().iter().filter_map(|s| s.block_prep).collect();
    assert_eq!(preps.len(), 4, "exactly K block pipelines ran");
    assert!(preps.iter().all(|t| t.reorder_secs > 0.0));
    // And the context still executes correctly.
    let x = vec![1.0; m.ncols()];
    assert_allclose(&ctx.spmv_alloc(&x).unwrap(), &m.spmv_f64_oracle(&x), 1e-9, 1e-9).unwrap();
}

#[test]
fn reordered_tuned_plans_key_the_store_on_the_reordered_structure() {
    let m = unstructured_mesh::<f64>(32, 32, 0.4, 13);
    let dir = std::env::temp_dir().join(format!("ehyb-reorder-store-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let build = |spec: Option<ReorderSpec>| {
        let mut b = SpmvContext::builder(m.clone())
            .engine(EngineKind::Ehyb)
            .config(cfg(64))
            .tune(TuneLevel::Heuristic)
            .plan_cache(&dir);
        if let Some(spec) = spec {
            b = b.reorder(spec);
        }
        b.build().unwrap()
    };
    let entries = || {
        std::fs::read_dir(&dir)
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .filter(|e| e.path().extension().is_some_and(|x| x == "json"))
                    .count()
            })
            .unwrap_or(0)
    };
    // Cold reordered build persists one entry under the REORDERED
    // fingerprint...
    let cold = build(Some(ReorderSpec::Rcm));
    assert_eq!(cold.tuned().unwrap().reorder, "rcm");
    assert_eq!(entries(), 1);
    // ...a warm rebuild adopts it (same winner, bitwise execution)...
    let warm = build(Some(ReorderSpec::Rcm));
    assert_eq!(warm.tuned(), cold.tuned());
    assert_eq!(entries(), 1, "warm start must not write a second entry");
    let x: Vec<f64> = (0..m.ncols()).map(|i| ((i * 13 + 3) % 23) as f64 * 0.25 - 2.5).collect();
    assert_eq!(cold.spmv_alloc(&x).unwrap(), warm.spmv_alloc(&x).unwrap());
    // ...and an unreordered build keys a DIFFERENT entry (reordered
    // winners survive restarts without colliding with natural-order
    // winners of the same matrix).
    let natural = build(None);
    assert_eq!(natural.tuned().unwrap().reorder, "none");
    assert_eq!(entries(), 2, "natural-order entry must not collide with the reordered one");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn reorder_rejects_non_square_with_typed_error() {
    let m = Coo::<f64>::new(3, 4).to_csr();
    match SpmvContext::builder(m).engine(EngineKind::CsrScalar).reorder(ReorderSpec::Rcm).build()
    {
        Err(ehyb::EhybError::UnsupportedFormat(_)) => {}
        other => panic!("expected UnsupportedFormat, got {:?}", other.err()),
    }
    // ReorderSpec::None is a no-op and must keep working on any shape.
    let m = Coo::<f64>::new(3, 4).to_csr();
    let ctx = SpmvContext::builder(m)
        .engine(EngineKind::CsrScalar)
        .reorder(ReorderSpec::None)
        .build()
        .unwrap();
    assert!(ctx.reordering().is_none());
}
