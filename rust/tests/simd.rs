//! SIMD gate (ISSUE 9 acceptance): scalar-vs-simd twin contracts for
//! every engine kind, under whichever feature leg this test crate was
//! compiled with (CI runs the suite on both `default` and
//! `--no-default-features`).
//!
//! Per-kind contract (see `ehyb::util::lanes` for the two proofs the
//! bitwise rows rely on — per-lane fma-chain preservation and the
//! `+0.0`-pad fma identity):
//!
//! | kind          | simd leg                         | contract        |
//! |---------------|----------------------------------|-----------------|
//! | ehyb          | packed ELL walk + ER tail + SpMM | bitwise (finite)|
//! | sellp         | lane-packed slice walk           | bitwise (finite)|
//! | ell           | row-packed k-outer walk          | bitwise (finite)|
//! | hyb           | ELL leg packed, COO tail shared  | bitwise (finite)|
//! | cusparse-alg1 | packed 32-wide warp model        | bitwise, always |
//! | csr5          | two-phase product/segmented-sum  | 1e-9 allclose   |
//! | csr-scalar    | none — the strictly-ordered      | n/a (scalar on  |
//! |               | reference walk stays scalar      | every leg)      |
//! | merge         | none — control-flow dominated    | n/a (scalar on  |
//! |               | path-splitting, stays scalar     | every leg)      |
//!
//! csr5 is the one allclose row: its simd leg buffers unfused products
//! per tile before the (serial) segmented sum, re-associating each
//! row's fma chain. Everything lane-parallel keeps per-row k-ordered
//! fused chains and must match bit-for-bit.

use ehyb::preprocess::{EhybPlan, PreprocessConfig};
use ehyb::sparse::csr::Csr;
use ehyb::sparse::gen::{circuit, unstructured_mesh};
use ehyb::sparse::scalar::Scalar;
use ehyb::spmv::csr5::Csr5Like;
use ehyb::spmv::csr_vector::CsrVector;
use ehyb::spmv::ehyb_cpu::EhybCpu;
use ehyb::spmv::ell::EllEngine;
use ehyb::spmv::hyb::HybEngine;
use ehyb::spmv::sellp::SellPEngine;
use ehyb::spmv::SpmvEngine;
use ehyb::util::check::{assert_allclose, check_prop, default_cases};
use ehyb::util::Xoshiro256;
use ehyb::{EngineKind, SpmvContext};

fn rand_matrix<S: Scalar>(rng: &mut Xoshiro256) -> Csr<S> {
    if rng.next_below(2) == 0 {
        let nx = 8 + rng.next_below(20);
        let ny = 8 + rng.next_below(20);
        unstructured_mesh(nx, ny, 0.5, rng.next_below(1000) as u64)
    } else {
        circuit(200 + rng.next_below(300), 3 + rng.next_below(3), 0.05, rng.next_below(1000) as u64)
    }
}

fn rand_x<S: Scalar>(rng: &mut Xoshiro256, n: usize) -> Vec<S> {
    (0..n).map(|_| S::from_f64(rng.range_f64(-2.0, 2.0))).collect()
}

fn twin_pair<S: Scalar>(
    name: &str,
    scalar: impl Fn(&[S], &mut [S]),
    simd: impl Fn(&[S], &mut [S]),
    x: &[S],
    nrows: usize,
) -> Result<(), String> {
    let mut ys = vec![S::ZERO; nrows];
    let mut yv = vec![S::ZERO; nrows];
    scalar(x, &mut ys);
    simd(x, &mut yv);
    if ys != yv {
        return Err(format!("{name}: simd leg is not bitwise equal to the scalar twin"));
    }
    Ok(())
}

/// The lane-parallel engines: every simd leg bitwise equals its scalar
/// twin on random structures and finite inputs, f32 and f64.
#[test]
fn prop_simd_twins_bitwise_on_lane_parallel_kinds() {
    fn prop<S: Scalar>(rng: &mut Xoshiro256) -> Result<(), String> {
        let m = rand_matrix::<S>(rng);
        let x = rand_x::<S>(rng, m.ncols());
        let n = m.nrows();
        let sell = SellPEngine::new(&m);
        twin_pair("sellp", |x, y| sell.spmv_scalar(x, y), |x, y| sell.spmv_simd(x, y), &x, n)?;
        let hybe = HybEngine::new(&m);
        twin_pair("hyb", |x, y| hybe.spmv_scalar(x, y), |x, y| hybe.spmv_simd(x, y), &x, n)?;
        let alg1 = CsrVector::new(&m);
        twin_pair("alg1", |x, y| alg1.spmv_scalar(x, y), |x, y| alg1.spmv_simd(x, y), &x, n)?;
        // Dense-width ELL only where padding stays sane (hub rows in
        // the circuit generator would blow up nrows x max_nnz).
        if m.max_row_nnz() <= 32 {
            let elle = EllEngine::new(&m);
            twin_pair("ell", |x, y| elle.spmv_scalar(x, y), |x, y| elle.spmv_simd(x, y), &x, n)?;
        }
        Ok(())
    }
    check_prop("simd-twins-bitwise-f64", 0x51, default_cases(), prop::<f64>);
    check_prop("simd-twins-bitwise-f32", 0x52, default_cases(), prop::<f32>);
}

/// EHYB: the packed ELL walk + ER tail and the register-blocked SpMM
/// are bitwise against their scalar twins in the kernel (new-order)
/// index space.
#[test]
fn prop_ehyb_simd_twins_bitwise() {
    fn prop<S: Scalar>(rng: &mut Xoshiro256) -> Result<(), String> {
        let m = rand_matrix::<S>(rng);
        let plan =
            EhybPlan::build(&m, &PreprocessConfig::default()).map_err(|e| format!("{e:#}"))?;
        let e = EhybCpu::new(&plan);
        let padded = plan.matrix.padded_rows();
        let xp = rand_x::<S>(rng, padded);
        let mut ys = vec![S::ZERO; padded];
        let mut yv = vec![S::ZERO; padded];
        e.spmv_new_order_scalar(&xp, &mut ys);
        e.spmv_new_order_simd(&xp, &mut yv);
        if ys != yv {
            return Err("ehyb ELL walk + ER tail: simd leg not bitwise".into());
        }
        // Register-blocked SpMM, 3 vectors (drives the NB=2+1 blocks).
        let xs: Vec<Vec<S>> = (0..3).map(|_| rand_x::<S>(rng, padded)).collect();
        let xrefs: Vec<&[S]> = xs.iter().map(|v| v.as_slice()).collect();
        let mut ys_b: Vec<Vec<S>> = (0..3).map(|_| vec![S::ZERO; padded]).collect();
        let mut yv_b: Vec<Vec<S>> = (0..3).map(|_| vec![S::ZERO; padded]).collect();
        {
            let mut yrefs: Vec<&mut [S]> = ys_b.iter_mut().map(|v| v.as_mut_slice()).collect();
            e.spmm_new_order_with(&xrefs, &mut yrefs, false);
        }
        {
            let mut yrefs: Vec<&mut [S]> = yv_b.iter_mut().map(|v| v.as_mut_slice()).collect();
            e.spmm_new_order_with(&xrefs, &mut yrefs, true);
        }
        if ys_b != yv_b {
            return Err("ehyb blocked SpMM: simd leg not bitwise".into());
        }
        Ok(())
    }
    check_prop("ehyb-simd-bitwise-f64", 0x53, default_cases(), prop::<f64>);
    check_prop("ehyb-simd-bitwise-f32", 0x54, default_cases(), prop::<f32>);
}

/// CSR5's two-phase simd leg re-associates fma into mul-then-add:
/// allclose to the scalar twin (and to the f64 oracle), never asserted
/// bitwise — that looseness is the documented contract for this kind.
#[test]
fn prop_csr5_simd_twin_allclose() {
    fn prop<S: Scalar>(rng: &mut Xoshiro256) -> Result<(), String> {
        let m = rand_matrix::<S>(rng);
        let x = rand_x::<S>(rng, m.ncols());
        let e = Csr5Like::new(&m);
        let mut ys = vec![S::ZERO; m.nrows()];
        let mut yv = vec![S::ZERO; m.nrows()];
        e.spmv_scalar(&x, &mut ys);
        e.spmv_simd(&x, &mut yv);
        let ys64: Vec<f64> = ys.iter().map(|v| v.to_f64()).collect();
        let yv64: Vec<f64> = yv.iter().map(|v| v.to_f64()).collect();
        let (rtol, atol) = if S::BYTES == 4 { (1e-4, 1e-5) } else { (1e-9, 1e-12) };
        assert_allclose(&yv64, &ys64, rtol, atol).map_err(|e| format!("csr5 twins: {e}"))?;
        let oracle = m.spmv_f64_oracle(&x);
        let (rtol, atol) = if S::BYTES == 4 { (1e-3, 1e-4) } else { (1e-9, 1e-10) };
        assert_allclose(&yv64, &oracle, rtol, atol).map_err(|e| format!("csr5 oracle: {e}"))
    }
    check_prop("csr5-simd-allclose-f64", 0x55, default_cases(), prop::<f64>);
    check_prop("csr5-simd-allclose-f32", 0x56, default_cases(), prop::<f32>);
}

/// The plain `spmv` entry points must route to exactly the leg the
/// compiled feature set selects — checked bitwise against the explicit
/// twin on this crate's own feature leg.
#[test]
fn plain_entry_points_dispatch_to_the_compiled_feature_leg() {
    let m = unstructured_mesh::<f64>(24, 24, 0.5, 77);
    let x: Vec<f64> = (0..m.ncols()).map(|i| ((i * 13 + 5) % 23) as f64 * 0.125 - 1.0).collect();
    let simd_on = cfg!(feature = "simd");
    let mut y_plain = vec![0.0; m.nrows()];
    let mut y_leg = vec![0.0; m.nrows()];
    let mut check = |name: &str,
                     plain: &mut dyn FnMut(&[f64], &mut [f64]),
                     scalar: &mut dyn FnMut(&[f64], &mut [f64]),
                     simd: &mut dyn FnMut(&[f64], &mut [f64])| {
        plain(&x, &mut y_plain);
        if simd_on {
            simd(&x, &mut y_leg);
        } else {
            scalar(&x, &mut y_leg);
        }
        assert_eq!(
            y_plain, y_leg,
            "{name}: plain spmv must dispatch to the {} leg",
            if simd_on { "simd" } else { "scalar" }
        );
    };
    let sell = SellPEngine::new(&m);
    check(
        "sellp",
        &mut |x, y| sell.spmv(x, y),
        &mut |x, y| sell.spmv_scalar(x, y),
        &mut |x, y| sell.spmv_simd(x, y),
    );
    let elle = EllEngine::new(&m);
    check(
        "ell",
        &mut |x, y| elle.spmv(x, y),
        &mut |x, y| elle.spmv_scalar(x, y),
        &mut |x, y| elle.spmv_simd(x, y),
    );
    let hybe = HybEngine::new(&m);
    check(
        "hyb",
        &mut |x, y| hybe.spmv(x, y),
        &mut |x, y| hybe.spmv_scalar(x, y),
        &mut |x, y| hybe.spmv_simd(x, y),
    );
    let alg1 = CsrVector::new(&m);
    check(
        "alg1",
        &mut |x, y| alg1.spmv(x, y),
        &mut |x, y| alg1.spmv_scalar(x, y),
        &mut |x, y| alg1.spmv_simd(x, y),
    );
    let c5 = Csr5Like::new(&m);
    check(
        "csr5",
        &mut |x, y| c5.spmv(x, y),
        &mut |x, y| c5.spmv_scalar(x, y),
        &mut |x, y| c5.spmv_simd(x, y),
    );
}

/// csr-scalar and merge deliberately have no simd leg (csr-scalar is
/// the strictly-ordered reference walk; merge's two-pointer path split
/// is control-flow dominated). On either feature leg they must stay
/// deterministic and oracle-exact.
#[test]
fn scalar_only_kinds_unchanged_by_the_feature_leg() {
    let m = unstructured_mesh::<f64>(20, 22, 0.5, 31);
    let x: Vec<f64> = (0..m.ncols()).map(|i| ((i * 7 + 2) % 19) as f64 * 0.25 - 2.0).collect();
    let oracle = m.spmv_f64_oracle(&x);
    for kind in [EngineKind::CsrScalar, EngineKind::Merge] {
        let ctx = SpmvContext::builder(m.clone()).engine(kind).build().expect("build");
        let e = ctx.engine();
        let mut y1 = vec![0.0; m.nrows()];
        let mut y2 = vec![0.0; m.nrows()];
        e.spmv(&x, &mut y1);
        e.spmv(&x, &mut y2);
        assert_eq!(y1, y2, "{}: nondeterministic", e.name());
        assert_allclose(&y1, &oracle, 1e-10, 1e-12)
            .unwrap_or_else(|err| panic!("{} vs oracle: {err}", e.name()));
    }
}
