//! Integration tests for the PJRT path: load the AOT artifacts built by
//! `make artifacts`, execute the EHYB SpMV through XLA, and compare
//! against the CSR oracle. These are the proof that all three layers
//! compose: L1 Pallas kernel → L2 JAX graph → HLO text → L3 Rust/PJRT.
//!
//! Skipped (with a loud message) when artifacts are missing, and
//! compiled out entirely without the `pjrt` feature (the default build
//! uses the stub client, whose `PjrtRuntime::new` always errors — these
//! tests would panic instead of skip).
#![cfg(feature = "pjrt")]

use ehyb::preprocess::{EhybPlan, PreprocessConfig};
use ehyb::runtime::PjrtRuntime;
use ehyb::sparse::gen::{poisson2d, poisson3d, unstructured_mesh};
use ehyb::util::check::assert_allclose;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

fn plan_for(m: &ehyb::sparse::csr::Csr<f64>, vec_size: usize) -> EhybPlan<f64> {
    EhybPlan::build(
        m,
        &PreprocessConfig { vec_size_override: Some(vec_size), ..Default::default() },
    )
    .unwrap()
}

#[test]
fn pjrt_spmv_matches_oracle_poisson2d() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::new(dir).unwrap();
    let m = poisson2d::<f64>(16, 16);
    let plan = plan_for(&m, 64);
    let engine = rt.spmv_engine(&plan.matrix).unwrap();
    let x: Vec<f64> = (0..256).map(|i| (i as f64 * 0.1).sin()).collect();
    let mut y = vec![0.0; 256];
    engine.spmv(&x, &mut y).unwrap();
    let oracle = m.spmv_f64_oracle(&x);
    assert_allclose(&y, &oracle, 1e-10, 1e-12).unwrap();
}

#[test]
fn pjrt_spmv_matches_oracle_unstructured_f32() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::new(dir).unwrap();
    let m = unstructured_mesh::<f32>(24, 24, 0.5, 7);
    let plan = EhybPlan::build(
        &m,
        &PreprocessConfig { vec_size_override: Some(128), ..Default::default() },
    )
    .unwrap();
    let engine = rt.spmv_engine(&plan.matrix).unwrap();
    let n = m.nrows();
    let x: Vec<f32> = (0..n).map(|i| ((i * 13 % 31) as f32) * 0.25 - 2.0).collect();
    let mut y = vec![0.0f32; n];
    engine.spmv(&x, &mut y).unwrap();
    let oracle = m.spmv_f64_oracle(&x);
    let y64: Vec<f64> = y.iter().map(|&v| v as f64).collect();
    assert_allclose(&y64, &oracle, 1e-4, 1e-4).unwrap();
}

#[test]
fn pjrt_matches_cpu_engine() {
    // PJRT result should agree with the CPU EHYB engine to fp tolerance
    // (not bitwise — XLA reassociates), across several matrices.
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::new(dir).unwrap();
    for (m, v) in [
        (poisson3d::<f64>(8, 8, 4), 64usize),
        (unstructured_mesh::<f64>(16, 16, 0.3, 3), 64),
    ] {
        let plan = plan_for(&m, v);
        let pjrt = rt.spmv_engine(&plan.matrix).unwrap();
        let cpu = ehyb::spmv::ehyb_cpu::EhybCpu::new(&plan);
        use ehyb::spmv::SpmvEngine;
        let n = m.nrows();
        let x: Vec<f64> = (0..n).map(|i| ((i * 7 + 1) % 19) as f64 * 0.5 - 4.0).collect();
        let mut y1 = vec![0.0; n];
        pjrt.spmv(&x, &mut y1).unwrap();
        let mut y2 = vec![0.0; n];
        cpu.spmv(&x, &mut y2);
        assert_allclose(&y1, &y2, 1e-11, 1e-11).unwrap();
    }
}

#[test]
fn pjrt_repeated_calls_consistent() {
    // Matrix literals are uploaded once; repeated executions must not
    // corrupt state.
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::new(dir).unwrap();
    let m = poisson2d::<f64>(16, 16);
    let plan = plan_for(&m, 64);
    let engine = rt.spmv_engine(&plan.matrix).unwrap();
    let x: Vec<f64> = (0..256).map(|i| (i % 11) as f64).collect();
    let mut y0 = vec![0.0; 256];
    engine.spmv(&x, &mut y0).unwrap();
    for _ in 0..5 {
        let mut y = vec![0.0; 256];
        engine.spmv(&x, &mut y).unwrap();
        assert_eq!(y, y0);
    }
}

#[test]
fn pjrt_executable_cache_shared() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::new(dir).unwrap();
    let m = poisson2d::<f64>(16, 16);
    let plan = plan_for(&m, 64);
    // Two engines over the same bucket exercise the compile cache.
    let e1 = rt.spmv_engine(&plan.matrix).unwrap();
    let e2 = rt.spmv_engine(&plan.matrix).unwrap();
    let x = vec![1.0; 256];
    let mut y1 = vec![0.0; 256];
    let mut y2 = vec![0.0; 256];
    e1.spmv(&x, &mut y1).unwrap();
    e2.spmv(&x, &mut y2).unwrap();
    assert_eq!(y1, y2);
}

#[test]
fn pjrt_fused_cg_step_artifact_solves() {
    // The second artifact kind: the whole CG iteration fused into one
    // executable (model.cg_step). Must converge to the same solution as
    // the host-side CG.
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::new(dir).unwrap();
    let m = poisson2d::<f64>(16, 16);
    let plan = plan_for(&m, 64);
    let n = m.nrows();
    let cg_engine = rt.cg_engine(&plan.matrix, &m.diagonal()).unwrap();
    let b: Vec<f64> = (0..n).map(|i| ((i % 5) as f64) - 2.0).collect();
    let (x, iters, converged) = cg_engine.solve(&b, 1e-9, 500).unwrap();
    assert!(converged, "fused CG did not converge in {iters} iters");
    let mut ax = vec![0.0; n];
    m.spmv(&x, &mut ax);
    assert_allclose(&ax, &b, 1e-6, 1e-7).unwrap();
    // Cross-check against the host solver's solution.
    let pre = ehyb::coordinator::Jacobi::new(&m);
    let (x_host, _) = ehyb::coordinator::cg(
        |v: &[f64], y: &mut [f64]| m.spmv(v, y),
        &b,
        &vec![0.0; n],
        &pre,
        &ehyb::coordinator::SolverConfig { rtol: 1e-9, ..Default::default() },
    );
    assert_allclose(&x, &x_host, 1e-5, 1e-6).unwrap();
}

#[test]
fn pjrt_cg_solver_end_to_end() {
    // CG through the PJRT SpMV: the full three-layer stack solving a
    // real SPD system.
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::new(dir).unwrap();
    let m = poisson2d::<f64>(16, 16);
    let plan = plan_for(&m, 64);
    let engine = rt.spmv_engine(&plan.matrix).unwrap();
    let n = m.nrows();
    let b: Vec<f64> = (0..n).map(|i| ((i % 7) as f64) - 3.0).collect();
    let pre = ehyb::coordinator::Jacobi::new(&m);
    let (x, rep) = ehyb::coordinator::cg(
        |v: &[f64], y: &mut [f64]| engine.spmv(v, y).unwrap(),
        &b,
        &vec![0.0; n],
        &pre,
        &ehyb::coordinator::SolverConfig::default(),
    );
    assert!(rep.converged(), "{rep:?}");
    let mut ax = vec![0.0; n];
    m.spmv(&x, &mut ax);
    assert_allclose(&ax, &b, 1e-6, 1e-6).unwrap();
}
