//! Default-build (no `pjrt` feature) behaviour of the runtime stub —
//! the path CI actually exercises: `PjrtRuntime::new` must fail with a
//! typed, actionable error so callers fall back to the CPU engines,
//! and the rest of the pipeline must keep working without any PJRT
//! artifacts present. (The real-client integration tests live in
//! `runtime_pjrt.rs`, compiled only with `--features pjrt`.)
#![cfg(not(feature = "pjrt"))]

use ehyb::runtime::PjrtRuntime;
use ehyb::EhybError;

#[test]
fn stub_runtime_new_is_typed_runtime_error() {
    match PjrtRuntime::new("/definitely-missing-artifacts") {
        Err(EhybError::Runtime(msg)) => {
            assert!(msg.contains("pjrt"), "error should name the missing feature: {msg}");
        }
        Ok(_) => panic!("stub PjrtRuntime::new must not succeed"),
        Err(other) => panic!("expected EhybError::Runtime, got {other:?}"),
    }
}

#[test]
fn stub_runtime_fault_inside_service_is_engine_fault_not_panic() {
    // ISSUE 6 satellite: a deployment that unwraps the stub runtime on
    // the serving path panics *inside* the kernel — the service must
    // map that to a typed EngineFault reply (and respawn), never let
    // the panic cross the service boundary or abort the process.
    use ehyb::coordinator::service::{BatchKernel, SpmvService};
    use std::sync::atomic::Ordering;
    let svc: SpmvService<f64> = SpmvService::spawn(
        || {
            let kernel: BatchKernel<f64> = Box::new(|_xs, _ys| {
                let _ = PjrtRuntime::new("/definitely-missing-artifacts").unwrap();
            });
            Ok((kernel, 0))
        },
        8,
        4,
    )
    .unwrap();
    let client = svc.client();
    match client.spmv(vec![1.0; 8]) {
        Err(EhybError::EngineFault(msg)) => {
            assert!(msg.contains("pjrt"), "fault should carry the stub's message: {msg}");
        }
        other => panic!("expected EngineFault, got {other:?}"),
    }
    assert_eq!(svc.metrics.faults.load(Ordering::Relaxed), 1);
    assert_eq!(svc.metrics.respawns.load(Ordering::Relaxed), 1);
}

#[test]
fn pipeline_works_without_pjrt() {
    // The artifact-missing fallback: the full facade pipeline runs on
    // the CPU engines with the stub compiled in.
    use ehyb::sparse::gen::poisson2d;
    let m = poisson2d::<f64>(12, 12);
    let ctx = ehyb::SpmvContext::new(m.clone()).unwrap();
    let x = vec![1.0; 144];
    let y = ctx.spmv_alloc(&x).unwrap();
    let oracle = m.spmv_f64_oracle(&x);
    for (a, b) in y.iter().zip(&oracle) {
        assert!((a - b).abs() < 1e-10);
    }
}
