//! Default-build (no `pjrt` feature) behaviour of the runtime stub —
//! the path CI actually exercises: `PjrtRuntime::new` must fail with a
//! typed, actionable error so callers fall back to the CPU engines,
//! and the rest of the pipeline must keep working without any PJRT
//! artifacts present. (The real-client integration tests live in
//! `runtime_pjrt.rs`, compiled only with `--features pjrt`.)
#![cfg(not(feature = "pjrt"))]

use ehyb::runtime::PjrtRuntime;
use ehyb::EhybError;

#[test]
fn stub_runtime_new_is_typed_runtime_error() {
    match PjrtRuntime::new("/definitely-missing-artifacts") {
        Err(EhybError::Runtime(msg)) => {
            assert!(msg.contains("pjrt"), "error should name the missing feature: {msg}");
        }
        Ok(_) => panic!("stub PjrtRuntime::new must not succeed"),
        Err(other) => panic!("expected EhybError::Runtime, got {other:?}"),
    }
}

#[test]
fn pipeline_works_without_pjrt() {
    // The artifact-missing fallback: the full facade pipeline runs on
    // the CPU engines with the stub compiled in.
    use ehyb::sparse::gen::poisson2d;
    let m = poisson2d::<f64>(12, 12);
    let ctx = ehyb::SpmvContext::new(m.clone()).unwrap();
    let x = vec![1.0; 144];
    let y = ctx.spmv_alloc(&x).unwrap();
    let oracle = m.spmv_f64_oracle(&x);
    for (a, b) in y.iter().zip(&oracle) {
        assert!((a - b).abs() < 1e-10);
    }
}
