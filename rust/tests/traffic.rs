//! Storage-traffic simulator gates (ISSUE 7 acceptance criteria):
//!
//! 1. conservation — at every modeled level `hits + misses == accesses`
//!    for every engine's replay over randomized matrices (the counters
//!    are tallied per probe AND per outcome, so this is a real check on
//!    the replay, not true by construction),
//! 2. the simulated DRAM traffic never undercuts the static compulsory
//!    floor ([`ehyb::perfmodel`]'s bounds) — the replay can only add
//!    sector rounding and capacity misses on top of it,
//! 3. replaying the same plan twice yields bit-identical counters (no
//!    RNG, no clocks, fixed iteration order),
//! 4. the headline: on the FEM-mesh suite the traffic-scored heuristic
//!    search never picks an engine that measures slower than the
//!    roofline-scored pick (the 0.6 behavior it replaces),
//! 5. the validation mode agrees with the measured winner on a
//!    majority of matrices.

use ehyb::autotune::{ScoreOracle, TuneLevel};
use ehyb::gpu::GpuDevice;
use ehyb::harness::traffic_validation;
use ehyb::perfmodel::{csr_bound, ehyb_bound};
use ehyb::preprocess::{EhybPlan, PreprocessConfig};
use ehyb::shard::{ShardPlan, ShardStrategy};
use ehyb::sparse::coo::Coo;
use ehyb::sparse::csr::Csr;
use ehyb::sparse::gen::{poisson2d, poisson3d, unstructured_mesh};
use ehyb::spmv::SpmvEngine;
use ehyb::traffic::{baseline_traffic, ehyb_traffic, shard_traffic, TrafficReport};
use ehyb::util::check::check_prop;
use ehyb::util::timer::bench_secs;
use ehyb::util::Xoshiro256;
use ehyb::{EngineKind, SpmvContext};
use std::time::Duration;

fn dev() -> GpuDevice {
    GpuDevice::v100()
}

/// Square matrix with a guaranteed diagonal (every column touched, so
/// the compulsory x floor is tight) plus random banded + scattered
/// off-diagonal entries.
fn random_matrix(rng: &mut Xoshiro256) -> Csr<f64> {
    let n = 16 + rng.next_below(240);
    let mut coo = Coo::new(n, n);
    for i in 0..n {
        coo.push(i, i, rng.range_f64(1.0, 4.0));
        let deg = rng.next_below(10);
        for _ in 0..deg {
            let j = if rng.next_f64() < 0.6 {
                let span = 24.min(n);
                (i + rng.next_below(span)).saturating_sub(span / 2).min(n - 1)
            } else {
                rng.next_below(n)
            };
            coo.push(i, j, rng.range_f64(-1.0, 1.0));
        }
    }
    coo.to_csr()
}

fn assert_conserves(r: &TrafficReport) -> Result<(), String> {
    for (tag, l) in [("shm", &r.shm), ("l2", &r.l2), ("dram", &r.dram)] {
        if l.hits + l.misses != l.accesses {
            return Err(format!(
                "{}/{tag}: hits {} + misses {} != accesses {}",
                r.name, l.hits, l.misses, l.accesses
            ));
        }
    }
    if r.shm.misses != 0 {
        return Err(format!("{}: explicit cache must never miss", r.name));
    }
    if r.dram.misses != 0 {
        return Err(format!("{}: DRAM is the backstop, it cannot miss", r.name));
    }
    if r.predicted_secs <= 0.0 {
        return Err(format!("{}: non-positive predicted time", r.name));
    }
    Ok(())
}

// ---------------------------------------------------------------- 1.

#[test]
fn prop_every_replay_conserves_probes() {
    let dev = dev();
    check_prop("traffic-conservation", 0x7AFF1C, 20, |rng| {
        let m = random_matrix(rng);
        for kind in EngineKind::ALL {
            assert_conserves(&baseline_traffic(kind, &m, &dev))?;
        }
        let cfg = PreprocessConfig::default();
        let plan = EhybPlan::build(&m, &cfg).map_err(|e| e.to_string())?;
        assert_conserves(&ehyb_traffic(&plan.matrix, &dev))?;
        let st = shard_traffic(&m, &ShardPlan::new(&m, 4, ShardStrategy::NnzBalanced), &dev);
        for s in &st.shards {
            assert_conserves(s)?;
        }
        Ok(())
    });
}

// ---------------------------------------------------------------- 2.

#[test]
fn simulated_dram_never_undercuts_compulsory_floor() {
    let dev = dev();
    let cases: Vec<(&str, Csr<f64>)> = vec![
        ("poisson2d-40", poisson2d(40, 40)),
        ("poisson3d-12", poisson3d(12, 12, 12)),
        ("mesh-44", unstructured_mesh(44, 44, 0.4, 11)),
    ];
    for (name, m) in &cases {
        let csr = baseline_traffic(EngineKind::CsrVector, m, &dev);
        let floor = csr_bound(m).compulsory_bytes();
        assert!(
            csr.dram_total_bytes() >= floor,
            "{name}: csr replay {} B under compulsory {floor} B",
            csr.dram_total_bytes()
        );
        let plan = EhybPlan::build(m, &PreprocessConfig::default()).unwrap();
        let e = ehyb_traffic(&plan.matrix, &dev);
        let efloor = ehyb_bound(&plan.matrix).compulsory_bytes();
        assert!(
            e.dram_total_bytes() >= efloor,
            "{name}: ehyb replay {} B under compulsory {efloor} B",
            e.dram_total_bytes()
        );
    }
}

// ---------------------------------------------------------------- 3.

#[test]
fn prop_counters_bit_identical_across_replays() {
    let dev = dev();
    check_prop("traffic-determinism", 0xB17B17, 12, |rng| {
        let m = random_matrix(rng);
        for kind in EngineKind::ALL {
            let a = baseline_traffic(kind, &m, &dev);
            let b = baseline_traffic(kind, &m, &dev);
            if a != b {
                return Err(format!("{}: replay not deterministic", kind.name()));
            }
        }
        let plan = EhybPlan::build(&m, &PreprocessConfig::default()).map_err(|e| e.to_string())?;
        if ehyb_traffic(&plan.matrix, &dev) != ehyb_traffic(&plan.matrix, &dev) {
            return Err("ehyb replay not deterministic".into());
        }
        let sp = ShardPlan::new(&m, 3, ShardStrategy::CacheAware);
        let s1 = shard_traffic(&m, &sp, &dev);
        let s2 = shard_traffic(&m, &sp, &dev);
        if s1.shards != s2.shards
            || s1.halo_dram_bytes != s2.halo_dram_bytes
            || s1.halo_nnz != s2.halo_nnz
        {
            return Err("shard replay not deterministic".into());
        }
        Ok(())
    });
}

// ---------------------------------------------------------------- 4.

/// The PR's acceptance bar: swapping the heuristic oracle from the
/// static roofline to the replayed traffic simulation must never make
/// the picked engine measure *worse* on the FEM suite. When the two
/// oracles agree on the pick (the common case) this holds trivially;
/// when they differ, the traffic pick's wall clock must be within 10%
/// of the roofline pick's (generous noise floor for CI hosts).
#[test]
fn traffic_oracle_pick_never_measures_worse_than_roofline_pick() {
    let suite: Vec<(&str, Csr<f64>)> = vec![
        ("fem-mesh-40", unstructured_mesh(40, 40, 0.4, 5)),
        ("fem-mesh-52", unstructured_mesh(52, 52, 0.6, 9)),
        ("poisson2d-48", poisson2d(48, 48)),
        ("poisson3d-10", poisson3d(10, 10, 10)),
    ];
    let cfg = PreprocessConfig::default();
    let build = |m: &Csr<f64>, oracle: ScoreOracle| {
        SpmvContext::builder(m.clone())
            .engine(EngineKind::Auto)
            .config(cfg.clone())
            .no_plan_cache()
            .tune(TuneLevel::Heuristic)
            .score_oracle(oracle)
            .build()
            .expect("heuristic build")
    };
    for (name, m) in &suite {
        let traffic = build(m, ScoreOracle::Traffic);
        let roofline = build(m, ScoreOracle::Roofline);
        if traffic.kind() == roofline.kind() {
            continue; // same engine — identical measured score by definition
        }
        let x: Vec<f64> = (0..m.nrows()).map(|i| (i as f64 * 0.17).sin()).collect();
        let measure = |ctx: &SpmvContext<f64>| {
            let e = ctx.engine();
            let mut y = vec![0.0f64; e.nrows()];
            // Best of three benches — each already min-over-reps — so a
            // scheduler hiccup cannot fail the gate.
            (0..3)
                .map(|_| bench_secs(|| e.spmv(&x, &mut y), 3, Duration::from_millis(20)))
                .fold(f64::INFINITY, f64::min)
        };
        let t = measure(&traffic);
        let r = measure(&roofline);
        assert!(
            t <= 1.10 * r,
            "{name}: traffic pick {} measured {t:.3e}s, worse than roofline pick {} at {r:.3e}s",
            traffic.kind().name(),
            roofline.kind().name()
        );
    }
}

// ---------------------------------------------------------------- 5.

#[test]
fn validation_mode_agrees_on_majority_of_suite() {
    let suite: Vec<(&str, Csr<f64>)> = vec![
        ("poisson2d-32", poisson2d(32, 32)),
        ("poisson2d-48", poisson2d(48, 48)),
        ("mesh-36", unstructured_mesh(36, 36, 0.5, 3)),
        ("mesh-48", unstructured_mesh(48, 48, 0.3, 7)),
        ("poisson3d-9", poisson3d(9, 9, 9)),
    ];
    let cfg = PreprocessConfig::default();
    let rows: Vec<_> = suite
        .iter()
        .map(|(name, m)| traffic_validation(name, m, &cfg).expect("validation run"))
        .collect();
    let agreed = rows.iter().filter(|r| r.agree).count();
    assert!(
        agreed * 2 > rows.len(),
        "oracle agreed on only {agreed}/{} matrices: {:?}",
        rows.len(),
        rows.iter().map(|r| (&r.matrix, &r.simulated_pick, &r.measured_pick)).collect::<Vec<_>>()
    );
}
