//! Deterministic chaos suite for the resilience layer (ISSUE 6): a
//! seeded [`FaultPlan`] schedules engine panics, NaN-poisoned inputs,
//! torn plan-cache entries, and queue saturation, and every injected
//! fault must map to a typed `EhybError` or a recorded recovery —
//! never a hang, a process abort, or a silently wrong answer. The CLI
//! twin of this suite is `cargo run -- chaos --seed 7`.

use ehyb::autotune::{tune_with_fingerprint, PlanStore};
use ehyb::coordinator::service::{BatchKernel, SpmvService};
use ehyb::coordinator::SolverConfig;
use ehyb::preprocess::PreprocessConfig;
use ehyb::runtime::json::Json;
use ehyb::sparse::coo::Coo;
use ehyb::sparse::gen::poisson2d;
use ehyb::util::check::assert_allclose;
use ehyb::{
    EhybError, EngineKind, FaultInjector, FaultPlan, GuardLevel, RetryPolicy, SpmvContext,
    TuneLevel,
};
use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// The suite's canonical seed — the same one the CI gate passes to the
/// `chaos` subcommand, so a failure reproduces identically in both.
const SEED: u64 = 7;

fn context() -> SpmvContext<f64> {
    let m = poisson2d::<f64>(16, 16);
    SpmvContext::builder(m)
        .engine(EngineKind::Ehyb)
        .config(PreprocessConfig { vec_size_override: Some(64), ..Default::default() })
        .build()
        .unwrap()
}

/// Service whose kernel is wrapped by a [`FaultInjector`]: the plan's
/// scheduled call panics inside the engine, everything else passes
/// through to the real EHYB kernel.
fn faulting_service(ctx: &SpmvContext<f64>, plan: FaultPlan) -> (SpmvService<f64>, FaultInjector) {
    let inj = FaultInjector::new(plan);
    let engine = ctx.engine_arc();
    let inj_kernel = inj.clone();
    let svc = SpmvService::spawn(
        move || {
            let engine = engine.clone();
            let fb = engine.format_bytes();
            let kernel: BatchKernel<f64> = Box::new(move |xs, ys| engine.spmv_batch(xs, ys));
            Ok((inj_kernel.wrap_kernel(kernel), fb))
        },
        ctx.nrows(),
        8,
    )
    .unwrap();
    (svc, inj)
}

fn probe_x(n: usize) -> Vec<f64> {
    (0..n).map(|i| ((i % 13) as f64) * 0.25 - 1.5).collect()
}

#[test]
fn fault_plan_is_seed_deterministic_and_json_round_trips() {
    let plan = FaultPlan::from_seed(SEED);
    assert_eq!(plan, FaultPlan::from_seed(SEED), "same seed must give the same schedule");
    assert_ne!(plan, FaultPlan::from_seed(SEED + 1));
    let text = plan.to_json().dump();
    let back = FaultPlan::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back, plan, "JSON round-trip drifted: {text}");
    // Disabled fault classes survive the round-trip as JSON null.
    let partial = FaultPlan { nan_on_call: None, torn_cache_bytes: None, ..plan };
    let back = FaultPlan::from_json(&Json::parse(&partial.to_json().dump()).unwrap()).unwrap();
    assert_eq!(back, partial);
}

#[test]
fn scheduled_engine_panic_poisons_one_batch_and_service_recovers() {
    let ctx = context();
    let plan = FaultPlan::from_seed(SEED);
    let panic_on = plan.panic_on_call.expect("from_seed schedules a panic");
    let (svc, inj) = faulting_service(&ctx, plan);
    let client = svc.client();
    let x = probe_x(ctx.nrows());
    let want = ctx.matrix().spmv_f64_oracle(&x);
    // Every call before the scheduled one serves correctly.
    for call in 1..panic_on {
        let y = client.spmv(x.clone()).unwrap_or_else(|e| panic!("call {call} failed: {e}"));
        assert_allclose(&y, &want, 1e-12, 1e-12).unwrap();
    }
    // The scheduled call panics inside the kernel: exactly this request
    // gets the typed fault — the panic never crosses the service
    // boundary and the process never aborts.
    match client.spmv(x.clone()) {
        Err(EhybError::EngineFault(msg)) => {
            assert!(msg.contains("injected engine fault"), "{msg}");
        }
        other => panic!("expected EngineFault on call {panic_on}, got {other:?}"),
    }
    // The respawned engine serves the very next request correctly.
    let y = client.spmv(x.clone()).unwrap();
    assert_allclose(&y, &want, 1e-12, 1e-12).unwrap();
    assert_eq!(svc.metrics.faults.load(Ordering::Relaxed), 1);
    assert_eq!(svc.metrics.respawns.load(Ordering::Relaxed), 1);
    // The injector counted every kernel call, poisoned or not.
    assert_eq!(inj.calls(), panic_on + 1);
    // Poisoned batches never enter the execution accounting.
    assert_eq!(svc.metrics.requests.load(Ordering::Relaxed), panic_on);
}

#[test]
fn retry_policy_recovers_the_injected_fault_within_budget() {
    let ctx = context();
    // Panic on the first kernel call: the retry lands on the respawned
    // engine and the caller never observes the fault.
    let plan = FaultPlan { panic_on_call: Some(1), ..FaultPlan::from_seed(SEED) };
    let (svc, _inj) = faulting_service(&ctx, plan);
    let policy = RetryPolicy {
        max_attempts: 3,
        base_delay: Duration::from_micros(200),
        max_delay: Duration::from_millis(2),
        seed: SEED,
    };
    let x = probe_x(ctx.nrows());
    let y = svc.client().spmv_with_retry(x.clone(), &policy).unwrap();
    assert_allclose(&y, &ctx.matrix().spmv_f64_oracle(&x), 1e-12, 1e-12).unwrap();
    assert_eq!(svc.metrics.faults.load(Ordering::Relaxed), 1);
    assert_eq!(svc.metrics.respawns.load(Ordering::Relaxed), 1);
}

#[test]
fn expired_deadline_is_typed_and_never_occupies_kernel_width() {
    let ctx = context();
    let svc = ctx.serve(8).unwrap();
    let client = svc.client();
    let x = probe_x(ctx.nrows());
    // Already expired at submit time: whenever the drain happens, the
    // triage fires — deterministic without any gate.
    match client.spmv_deadline(x.clone(), Instant::now() - Duration::from_millis(5)) {
        Err(EhybError::DeadlineExceeded) => {}
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
    assert_eq!(svc.metrics.deadline_misses.load(Ordering::Relaxed), 1);
    // A live request on the same service still round-trips.
    let y = client.spmv_deadline(x.clone(), Instant::now() + Duration::from_secs(60)).unwrap();
    assert_allclose(&y, &ctx.matrix().spmv_f64_oracle(&x), 1e-12, 1e-12).unwrap();
    assert_eq!(svc.metrics.deadline_misses.load(Ordering::Relaxed), 1);
}

#[test]
fn saturation_sheds_exactly_the_flood_beyond_the_bound() {
    // Gate-driven depth-1 queue: r1 blocks inside the kernel, r2 holds
    // the only slot, and the plan's whole flood sheds with the typed
    // backpressure error — each shed handing its buffer back.
    let ctx = context();
    let n = ctx.nrows();
    let plan = FaultPlan::from_seed(SEED);
    let engine = ctx.engine_arc();
    let (started_tx, started_rx) = mpsc::channel::<()>();
    let (gate_tx, gate_rx) = mpsc::channel::<()>();
    let mut rig = Some((started_tx, gate_rx));
    let svc: SpmvService<f64> = SpmvService::spawn_bounded(
        move || {
            let engine = engine.clone();
            let fb = engine.format_bytes();
            let (stx, grx) = rig.take().expect("gated rig builds one engine");
            let kernel: BatchKernel<f64> = Box::new(move |xs, ys| {
                stx.send(()).unwrap();
                grx.recv().unwrap();
                engine.spmv_batch(xs, ys)
            });
            Ok((kernel, fb))
        },
        n,
        8,
        1,
    )
    .unwrap();
    let client = svc.client();
    let rx1 = client.submit(probe_x(n)).unwrap();
    started_rx.recv().unwrap(); // r1 is inside the kernel
    let rx2 = client.submit(probe_x(n)).unwrap(); // occupies the slot
    for i in 0..plan.saturate_requests {
        match client.try_submit(probe_x(n)) {
            Err((EhybError::Overloaded { queue_depth: 1 }, x)) => assert_eq!(x.len(), n),
            other => panic!("flood request {i}: expected Overloaded, got {:?}", other.map(|_| ())),
        }
    }
    assert_eq!(svc.metrics.shed.load(Ordering::Relaxed), plan.saturate_requests);
    // Release the two accepted drains; both complete correctly.
    gate_tx.send(()).unwrap();
    gate_tx.send(()).unwrap();
    let want = ctx.matrix().spmv_f64_oracle(&probe_x(n));
    assert_allclose(&rx1.recv().unwrap().unwrap(), &want, 1e-12, 1e-12).unwrap();
    assert_allclose(&rx2.recv().unwrap().unwrap(), &want, 1e-12, 1e-12).unwrap();
    // Sheds never enter the width histogram.
    assert_eq!(svc.metrics.batch_width.count(), svc.metrics.batches.load(Ordering::Relaxed));
    drop(gate_tx);
}

#[test]
fn nan_poisoned_input_is_rejected_or_monitored_never_silent() {
    let m = poisson2d::<f64>(16, 16);
    let cfg = PreprocessConfig { vec_size_override: Some(64), ..Default::default() };
    let plan = FaultPlan::from_seed(SEED);
    let call = plan.nan_on_call.expect("from_seed schedules a NaN");
    let inj = FaultInjector::new(plan);
    let mut x = probe_x(256);
    let idx = inj.poison(call, &mut x).expect("poison fires on its scheduled call");
    assert!(x[idx].is_nan());

    // Reject guard: the typed error names the poisoned index and the
    // rejection is recorded — the NaN never reaches the engine.
    let rctx = SpmvContext::builder(m.clone())
        .engine(EngineKind::Ehyb)
        .config(cfg.clone())
        .guard(GuardLevel::Reject)
        .build()
        .unwrap();
    match rctx.spmv_alloc(&x) {
        Err(EhybError::NonFinite { what: "x", index }) => assert_eq!(index, idx),
        other => panic!("expected NonFinite at {idx}, got {other:?}"),
    }
    assert_eq!(rctx.health().rejected_inputs, 1);

    // Monitor guard: the call proceeds but the non-finite output is
    // recorded — degraded data is visible, not silent.
    let mctx = SpmvContext::builder(m)
        .engine(EngineKind::CsrVector)
        .config(cfg)
        .guard(GuardLevel::Monitor)
        .build()
        .unwrap();
    let y = mctx.spmv_alloc(&x).unwrap();
    assert!(y.iter().any(|v| v.is_nan()), "NaN input must propagate under Monitor");
    let h = mctx.health();
    assert!(h.nonfinite_outputs >= 1);
    assert!(!h.healthy() && !h.degraded());
}

#[test]
fn torn_plan_cache_entry_is_quarantined_and_retuning_recovers() {
    let m = poisson2d::<f64>(16, 16);
    let cfg = PreprocessConfig { vec_size_override: Some(64), ..Default::default() };
    let dir = std::env::temp_dir().join(format!("ehyb-chaos-store-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let store = PlanStore::new(&dir);
    // A real tuned plan, persisted atomically...
    let out = tune_with_fingerprint(&m, &cfg, EngineKind::Ehyb, TuneLevel::Heuristic, None).unwrap();
    let p = out.plan;
    let path = store.save(&p).unwrap();
    // ...then torn mid-file by the injector (a crashed writer without
    // the temp-file + rename protocol).
    let inj = FaultInjector::new(FaultPlan::from_seed(SEED));
    assert!(inj.tear_file(&path).unwrap(), "from_seed schedules a tear");
    assert!(store.load(&p.fingerprint, &p.device, &p.dtype, &p.scope).is_err());
    assert_eq!(store.quarantines(), 1);
    // The damage is moved aside: the key reads as a cold miss and a
    // fresh tune re-occupies it.
    assert!(store.load(&p.fingerprint, &p.device, &p.dtype, &p.scope).unwrap().is_none());
    assert_eq!(store.quarantines(), 1);
    store.save(&p).unwrap();
    let back = store.load(&p.fingerprint, &p.device, &p.dtype, &p.scope).unwrap().unwrap();
    assert_eq!(back, p);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn failed_ehyb_build_degrades_to_baseline_and_is_recorded() {
    // EHYB preprocessing needs a square matrix; with fallback enabled
    // the build downgrades to csr-vector instead of failing — recorded
    // in health, and the degraded engine still computes correctly.
    let mut coo = Coo::<f64>::new(3, 4);
    coo.push(0, 0, 1.0);
    coo.push(0, 3, 2.0);
    coo.push(1, 1, 2.0);
    coo.push(2, 2, 2.0);
    let ctx = SpmvContext::builder(coo.to_csr())
        .engine(EngineKind::Ehyb)
        .fallback(true)
        .build()
        .unwrap();
    assert_eq!(ctx.kind(), EngineKind::CsrVector);
    assert_eq!(ctx.requested_kind(), EngineKind::Ehyb);
    let h = ctx.health();
    assert!(h.degraded());
    assert_eq!(h.engine_fallbacks, 1);
    assert_eq!(ctx.spmv_alloc(&[1.0; 4]).unwrap(), vec![3.0, 2.0, 2.0]);
    // Strict (default) contexts keep failing loudly.
    let mut coo = Coo::<f64>::new(3, 4);
    coo.push(0, 0, 1.0);
    assert!(SpmvContext::builder(coo.to_csr()).engine(EngineKind::Ehyb).build().is_err());
}

#[test]
fn diverging_solve_restarts_once_and_recovers() {
    // Jordan block [[1, 2], [0, 1]] with b = (0, 1): CG on this
    // nonsymmetric system diverges (residual grows immediately), the
    // fallback restart runs Jacobi-preconditioned BiCGSTAB, which
    // converges exactly to x = (-2, 1).
    let mut coo = Coo::<f64>::new(2, 2);
    coo.push(0, 0, 1.0);
    coo.push(0, 1, 2.0);
    coo.push(1, 1, 1.0);
    let ctx = SpmvContext::builder(coo.to_csr())
        .engine(EngineKind::CsrVector)
        .fallback(true)
        .build()
        .unwrap();
    let cfg = SolverConfig { divergence_window: 1, ..Default::default() };
    let b = [0.0, 1.0];
    let (x, rep) =
        ctx.solver().cg(&b, None, &ehyb::coordinator::precond::Identity, &cfg).unwrap();
    assert!(rep.converged(), "restart must converge: {rep:?}");
    assert_eq!(rep.solver, "bicgstab");
    assert_allclose(&x, &[-2.0, 1.0], 1e-10, 1e-10).unwrap();
    let h = ctx.health();
    assert_eq!(h.solver_restarts, 1);
    assert!(h.events.iter().any(|e| e.contains("diverged")), "{:?}", h.events);
}
