//! Profiling gate (ISSUE 10 acceptance): the observed-counter layer
//! through the public surface, on whichever feature leg this test
//! crate was compiled with (CI runs the suite on both `default` and
//! `--no-default-features --features simd`).
//!
//! Contracts:
//! - Kernels are bitwise identical with profiling on or off: every
//!   engine kind stays deterministic and oracle-exact on both legs,
//!   and the off-leg provably records nothing.
//! - Hand-computed byte counts match [`CallCost`] on fixed fixtures.
//! - Observed counters tie out exactly against the traffic replay of
//!   the same plan for EHYB and csr-vector at B=1, and against the
//!   fused-batch replay at B=4; any observed-vs-DRAM gap is then
//!   attributable to the cache model, never the stream model.
//! - `observe_drift` past the bound records a model-drift health event
//!   and stamps the cached plan so a warm start re-searches.
//! - Calibrations persist and reload through the plan store, and a
//!   tuner-routed build picks the persisted fit up automatically.

use ehyb::autotune::device_key;
use ehyb::gpu::device::GpuDevice;
use ehyb::preprocess::PreprocessConfig;
use ehyb::profile::{self, CalSample, CallCost};
use ehyb::sparse::gen::{poisson2d, unstructured_mesh};
use ehyb::traffic::{ehyb_batch_traffic, ehyb_traffic, spmm_register_blocks};
use ehyb::util::check::assert_allclose;
use ehyb::{BatchBuf, Calibration, EngineKind, PlanStore, SpmvContext, TuneLevel};

fn cfg64() -> PreprocessConfig {
    PreprocessConfig { vec_size_override: Some(64), ..Default::default() }
}

fn seeded_x(n: usize) -> Vec<f64> {
    (0..n).map(|i| ((i * 13 + 5) % 23) as f64 * 0.125 - 1.0).collect()
}

/// Every engine kind, on either feature leg: two runs are bitwise
/// equal, the result is oracle-exact, and the recording layer's
/// presence is exactly the compiled feature — which, run on both CI
/// legs, is the twin-identity gate (recording happens strictly after
/// the kernel computes, so the off-leg cannot change a bit).
#[test]
fn every_kind_deterministic_and_oracle_exact_on_this_leg() {
    let m = unstructured_mesh::<f64>(20, 20, 0.5, 7);
    let x = seeded_x(m.ncols());
    let oracle = m.spmv_f64_oracle(&x);
    for kind in EngineKind::ALL {
        let ctx = SpmvContext::builder(m.clone()).engine(kind).config(cfg64()).build().unwrap();
        let mut y1 = vec![0.0; ctx.nrows()];
        let mut y2 = vec![0.0; ctx.nrows()];
        ctx.spmv(&x, &mut y1).unwrap();
        ctx.spmv(&x, &mut y2).unwrap();
        assert_eq!(y1, y2, "{kind:?}: profiled run is nondeterministic");
        assert_allclose(&y1, &oracle, 1e-9, 1e-9).unwrap_or_else(|e| panic!("{kind:?}: {e}"));
        if !profile::enabled() {
            assert!(ctx.profile().is_none(), "{kind:?}: off-leg must record nothing");
            assert!(ctx.drift().is_none());
            continue;
        }
        // The instrumented hot paths; the remaining study kinds keep
        // the default no-profile implementation.
        let instrumented =
            matches!(kind, EngineKind::Ehyb | EngineKind::CsrScalar | EngineKind::CsrVector);
        match ctx.profile() {
            Some(p) => {
                assert!(instrumented, "{kind:?}: unexpected profile {p:?}");
                assert_eq!((p.calls, p.lanes), (2, 2), "{kind:?}");
                assert_eq!(p.flops, 2 * 2 * m.nnz() as u64, "{kind:?}");
                assert!(p.total_bytes() > 0 && p.secs > 0.0, "{kind:?}");
            }
            None => assert!(!instrumented, "{kind:?}: instrumented kind recorded nothing"),
        }
    }
    if !profile::enabled() {
        assert!(profile::timer().is_none(), "off-leg must never read the clock");
        assert_eq!(profile::elapsed(None), 0.0);
    }
}

/// Hand-computed byte counts on the 2x2 Poisson fixture (4 rows of 3
/// nonzeros each, tau = 8): the CSR walk streams nnz (4 + tau) format
/// bytes, 8 nrows of row pointers, nnz tau gather bytes, nrows tau
/// writes — and all 32 bytes of x fit one 64-byte line.
#[test]
fn csr_call_cost_matches_hand_count() {
    let m = poisson2d::<f64>(2, 2);
    assert_eq!((m.nrows(), m.nnz()), (4, 12), "fixture drifted");
    let c = CallCost::of_csr(&m);
    assert_eq!(c.ell_stream, 12 * (4 + 8));
    assert_eq!(c.meta_block, 8 * 4);
    assert_eq!(c.x_gather, 12 * 8);
    assert_eq!(c.write, 4 * 8);
    assert_eq!(c.x_lines, 1);
    assert_eq!(c.flops, 24);
    assert_eq!((c.er_stream, c.meta_lane, c.x_fill, c.pad_slots), (0, 0, 0, 0));
    assert_eq!(c.lane_bytes(), 144 + 32 + 96 + 32);
}

/// The EHYB cost re-derived from the format's public fields (slice
/// slots, ER slots, descriptor widths) matches [`CallCost::of_ehyb`]
/// and, component for component, the traffic replay of the same plan.
#[test]
fn ehyb_call_cost_matches_format_fields_and_replay() {
    let m = unstructured_mesh::<f64>(40, 40, 0.5, 5);
    let ctx =
        SpmvContext::builder(m).engine(EngineKind::Ehyb).config(cfg64()).build().unwrap();
    let e = &ctx.plan().expect("ehyb context has a plan").matrix;
    let cost = CallCost::of_ehyb(e);
    let tau = 8u64;
    let h = e.slice_height as u64;
    let (ell_slots, er_slots) = (e.ell_vals.len() as u64, e.er_vals.len() as u64);
    let er_slices = e.er_slice_width.len() as u64;
    let padded = e.padded_rows() as u64;
    assert_eq!(cost.ell_stream, ell_slots * (2 + tau), "values + u16 cols per slot");
    assert_eq!(cost.er_stream, er_slots * (4 + tau), "values + u32 cols per slot");
    assert_eq!(cost.meta_block, 8 * e.num_slices() as u64, "slice ptr/width pairs");
    assert_eq!(cost.meta_lane, er_slices * (8 + 4 * h), "ER descriptors + y_idx_er");
    assert_eq!(cost.x_fill, padded * tau, "explicit cache fills every padded row");
    assert_eq!(cost.x_gather, er_slots * tau, "only the ER tail gathers uncached");
    assert_eq!(cost.write, padded * tau + er_slices * h * tau);
    assert_eq!(
        cost.pad_slots,
        (ell_slots - e.ell_nnz as u64) + (er_slots - e.er_nnz as u64)
    );
    assert_eq!(cost.er_scatter_rows, e.er_rows as u64);
    assert_eq!(cost.flops, 2 * e.nnz() as u64);
    // Component-for-component agreement with the simulator's replay.
    let r = ehyb_traffic(e, &GpuDevice::v100());
    let c = &r.components;
    assert_eq!(cost.ell_stream, c.ell);
    assert_eq!(cost.er_stream, c.er);
    assert_eq!(cost.meta_block + cost.meta_lane, c.meta);
    assert_eq!(cost.x_fill, c.x_fill);
    assert_eq!(cost.x_gather, c.x_gather);
    assert_eq!(cost.write, c.write);
    assert_eq!(cost.lane_bytes(), c.total());
}

/// The acceptance cross-check: what EHYB and csr-vector observably
/// moved at B=1 equals what the simulator predicted, per component;
/// any gap against the sector-granular DRAM figure is then cache
/// model, not stream model, and stays attributable.
#[test]
fn observed_matches_simulated_for_ehyb_and_csr_vector() {
    if !profile::enabled() {
        return;
    }
    let m = unstructured_mesh::<f64>(40, 40, 0.5, 5);
    let x = seeded_x(m.ncols());
    for kind in [EngineKind::Ehyb, EngineKind::CsrVector] {
        let ctx = SpmvContext::builder(m.clone()).engine(kind).config(cfg64()).build().unwrap();
        let mut y = vec![0.0; ctx.nrows()];
        for _ in 0..3 {
            ctx.spmv(&x, &mut y).unwrap();
        }
        let d = ctx.drift().expect("unsharded context replays its plan");
        assert_eq!(d.lanes, 3);
        assert_eq!(d.max_rel_drift(), 0.0, "{kind:?}: {d:?}");
        assert!(!d.exceeded() && !d.calibrated, "{kind:?}");
        assert_eq!(d.bytes_drift(), 0.0, "{kind:?}");
        // Observed logical bytes vs the simulator's DRAM figure: within
        // the bound, or — with every stream component tying out exactly
        // (asserted above) — the gap is the L2/sector cache model, the
        // named attribution the report's markdown prints.
        if d.dram_drift() > profile::DEFAULT_DRIFT_THRESHOLD {
            assert!(
                d.observed_bytes > d.predicted_dram_bytes as f64,
                "{kind:?}: DRAM exceeding logical bytes cannot be cache reuse: {d:?}"
            );
        }
    }
}

/// Fused-batch observation vs the fused-batch replay at B=4: the
/// matrix stream is charged once per register block on both sides, the
/// per-lane streams four times.
#[test]
fn batch_observation_ties_out_against_the_batch_replay() {
    let m = unstructured_mesh::<f64>(28, 28, 0.5, 11);
    let ctx =
        SpmvContext::builder(m.clone()).engine(EngineKind::Ehyb).config(cfg64()).build().unwrap();
    let n = ctx.nrows();
    let xs: Vec<Vec<f64>> = (0..4)
        .map(|t| (0..n).map(|i| ((i * 7 + t * 11 + 3) % 19) as f64 * 0.25 - 2.0).collect())
        .collect();
    let xrefs: Vec<&[f64]> = xs.iter().map(|v| v.as_slice()).collect();
    let xbatch = BatchBuf::from_cols(&xrefs).unwrap();
    let mut ybatch = BatchBuf::<f64>::zeros(n, xs.len());
    {
        let mut yv = ybatch.view_mut();
        ctx.spmv_batch(xbatch.view(), &mut yv).unwrap();
    }
    if !profile::enabled() {
        assert!(ctx.profile().is_none());
        return;
    }
    let p = ctx.profile().expect("batched call recorded");
    assert_eq!((p.calls, p.lanes), (1, 4));
    assert_eq!(p.spmm_blocks, spmm_register_blocks(4).len() as u64);
    assert!((p.tile_reuse() - 4.0 / p.spmm_blocks as f64).abs() < 1e-12);
    let r = ehyb_batch_traffic(&ctx.plan().unwrap().matrix, &GpuDevice::v100(), 4);
    let c = &r.components;
    assert_eq!(p.ell_bytes, c.ell, "matrix stream charged once per register block");
    assert_eq!(p.er_bytes, c.er);
    assert_eq!(p.meta_bytes, c.meta);
    assert_eq!(p.x_fill_bytes, c.x_fill);
    assert_eq!(p.x_gather_bytes, c.x_gather);
    assert_eq!(p.write_bytes, c.write);
}

/// The drift loop through the public surface: a calibration that
/// cannot describe any host makes `observe_drift` trip the bound,
/// record a model-drift health event, and stamp the cached plan —
/// after which a warm start under the default bound re-searches while
/// a permissive bound still adopts the stamped entry.
#[test]
fn observed_drift_records_health_and_invalidates_the_cached_plan() {
    if !profile::enabled() {
        return;
    }
    let m = unstructured_mesh::<f64>(32, 32, 0.4, 5);
    let dir = std::env::temp_dir().join(format!("ehyb-test-profile-drift-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let bogus = Calibration {
        dram_secs_per_byte: 0.0,
        l2_secs_per_byte: 0.0,
        shm_secs_per_byte: 0.0,
        base_secs: 0.0,
        samples: 2,
        residual: 0.0,
    };
    let mut ctx = SpmvContext::builder(m.clone())
        .engine(EngineKind::Ehyb)
        .config(cfg64())
        .tune(TuneLevel::Heuristic)
        .plan_cache(&dir)
        .calibration(bogus)
        .build()
        .unwrap();
    let x = seeded_x(ctx.ncols());
    let mut y = vec![0.0; ctx.nrows()];
    ctx.spmv(&x, &mut y).unwrap();
    let d = ctx.observe_drift().expect("observation");
    assert!(d.calibrated && d.exceeded(), "zero-secs calibration must drift: {d:?}");
    let h = ctx.health();
    assert_eq!(h.model_drifts, 1);
    assert!(!h.healthy() && !h.degraded(), "drift observes, it does not degrade");
    let stamp = d.stamp();
    assert_eq!(ctx.tuned().unwrap().drift, Some(stamp));
    // Permissive bound first: it must adopt the stamped entry as-is
    // (a default-bound build would re-search and overwrite the cache).
    let adopted = SpmvContext::builder(m.clone())
        .engine(EngineKind::Ehyb)
        .config(cfg64())
        .tune(TuneLevel::Heuristic)
        .plan_cache(&dir)
        .drift_threshold(2.0)
        .build()
        .unwrap();
    assert_eq!(adopted.tuned().unwrap().drift, Some(stamp));
    let fresh = SpmvContext::builder(m)
        .engine(EngineKind::Ehyb)
        .config(cfg64())
        .tune(TuneLevel::Heuristic)
        .plan_cache(&dir)
        .build()
        .unwrap();
    assert_eq!(fresh.tuned().unwrap().drift, None, "drifted plan must be re-searched");
    std::fs::remove_dir_all(&dir).ok();
}

/// Calibration persistence: fit -> save -> load round-trips through
/// the plan store, a damaged entry is quarantined not trusted, and a
/// tuner-routed build auto-loads the persisted fit for its device key.
#[test]
fn calibration_round_trips_through_the_plan_store() {
    let dir = std::env::temp_dir().join(format!("ehyb-test-profile-cal-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let store = PlanStore::new(&dir);
    let samples: Vec<CalSample> = [(1u64, 3u64), (2, 1), (5, 4), (9, 2)]
        .iter()
        .map(|&(i, j)| CalSample {
            dram_bytes: i as f64 * 1e6,
            // i*j keeps the features linearly independent.
            l2_bytes: (i * j + 1) as f64 * 2e6,
            shm_bytes: j as f64 * 5e5,
            measured_secs: i as f64 * 2e-6 + j as f64 * 1e-6 + 3e-6,
        })
        .collect();
    let cal = Calibration::fit(&samples).expect("well-posed fit");
    assert_eq!(cal.samples, 4);
    assert!(cal.residual.is_finite());
    let cfg = PreprocessConfig::default();
    let key = device_key(&cfg.device);
    store.save_calibration(&cal, &key, "f64").unwrap();
    assert_eq!(store.load_calibration(&key, "f64").unwrap(), Some(cal.clone()));
    assert!(store.load_calibration("other-device", "f64").unwrap().is_none());
    // A tuner-routed EHYB build picks the persisted fit up by itself.
    let ctx = SpmvContext::builder(unstructured_mesh::<f64>(24, 24, 0.5, 3))
        .engine(EngineKind::Ehyb)
        .config(cfg)
        .tune(TuneLevel::Heuristic)
        .plan_cache(&dir)
        .build()
        .unwrap();
    assert_eq!(ctx.calibration(), Some(&cal));
    // Damage quarantines instead of trusting the bytes.
    std::fs::write(store.calibration_path(&key, "f64"), "{not json").unwrap();
    assert!(store.load_calibration(&key, "f64").is_err());
    assert_eq!(store.quarantines(), 1);
    assert!(store.load_calibration(&key, "f64").unwrap().is_none(), "quarantine moved it");
    std::fs::remove_dir_all(&dir).ok();
}
