//! Bench: regenerate **Figure 6** — preprocessing time (partitioning +
//! reordering) in units of one SpMV on the 16 commonly-tested matrices.
//! Reports both the simulated-V100 unit (the paper's) and a CPU-engine
//! unit as a wall-clock cross-check. `cargo bench --bench fig6_preprocessing`.

use ehyb::gpu::GpuDevice;
use ehyb::harness::{report, runner, suite, tables};
use ehyb::preprocess::PreprocessConfig;

fn main() {
    let scale = suite::Scale::from_env();
    let dev = GpuDevice::v100();
    let specs = suite::suite16(scale);
    let mut runs = Vec::new();
    println!("| matrix | partition (xSpMV-cpu) | reorder (xSpMV-cpu) |");
    println!("|---|---|---|");
    for spec in &specs {
        let m = spec.build();
        let cfg = PreprocessConfig::default();
        // CPU wall-clock cross-check.
        if let Ok((prep, cpu_spmv)) = runner::measure_prep_ratio_cpu(&m, &cfg) {
            let u = prep.in_spmv_units(cpu_spmv);
            println!("| {} | {:.0} | {:.0} |", spec.name, u.partition, u.reorder);
        }
        // Simulated-GPU unit (the paper's axis).
        if let Ok(r) = runner::run_matrix(&spec.name, spec.category, &m, &cfg, &dev) {
            runs.push(r);
        }
    }
    println!("\nFigure 6 — preprocessing in units of one simulated-V100 SpMV:");
    let rows = tables::fig6_rows(&runs);
    println!("{}", report::fig6_markdown(&rows));
    std::fs::create_dir_all("bench_out").ok();
    std::fs::write("bench_out/fig6_preprocessing.md", report::fig6_markdown(&rows)).ok();

    // Paper's claimed band: partition 400-1500x, reorder 50-400x,
    // total 500-2000x (on their testbed). Report our band.
    let (mut pmin, mut pmax, mut tmin, mut tmax) = (f64::MAX, 0.0f64, f64::MAX, 0.0f64);
    for r in &rows {
        pmin = pmin.min(r.partition_x);
        pmax = pmax.max(r.partition_x);
        tmin = tmin.min(r.total_x);
        tmax = tmax.max(r.total_x);
    }
    println!(
        "measured bands: partitioning {pmin:.0}-{pmax:.0}x, total {tmin:.0}-{tmax:.0}x \
         (paper: 400-1500x / 500-2000x)"
    );
}
