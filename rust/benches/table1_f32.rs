//! Bench: regenerate **Table 1** (single-precision EHYB speedups over
//! yaSpMV / holaSpMV / CSR5 / merge / cuSPARSE ALG1+2 on the 94-matrix
//! corpus) and the **Figure 2** series. Custom harness (no criterion in
//! the offline closure) — run with `cargo bench --bench table1_f32`.
//! Scale via EHYB_SUITE_SCALE=tiny|small|full (default small).

use ehyb::gpu::GpuDevice;
use ehyb::harness::{report, runner, suite, tables};
use ehyb::preprocess::PreprocessConfig;
use ehyb::sparse::csr::Csr;

fn main() {
    let scale = suite::Scale::from_env();
    let dev = GpuDevice::v100();
    let specs = suite::suite94(scale);
    eprintln!("table1_f32: {} matrices at {scale:?}", specs.len());
    let mut runs = Vec::new();
    for (i, spec) in specs.iter().enumerate() {
        let m: Csr<f32> = spec.build().cast();
        match runner::run_matrix(&spec.name, spec.category, &m, &PreprocessConfig::default(), &dev)
        {
            Ok(r) => {
                eprintln!(
                    "[{}/{}] {} ehyb={:.1}GF vs alg2 {:.2}x",
                    i + 1,
                    specs.len(),
                    spec.name,
                    r.gflops_of("ehyb").unwrap_or(0.0),
                    r.speedup_vs("cusparse-alg2").unwrap_or(0.0)
                );
                runs.push(r);
            }
            Err(e) => eprintln!("[{}/{}] {} failed: {e:#}", i + 1, specs.len(), spec.name),
        }
    }
    let table = tables::speedup_table::<f32>(&runs);
    let title1 = "Table 1 — EHYB speedup, single precision (simulated V100)";
    println!("{}", report::speedup_markdown(title1, &table));
    let fig = tables::figure_series::<f32>(&runs);
    println!("Figure 2 summary:\n{}", report::figure_summary(&fig));
    std::fs::create_dir_all("bench_out").ok();
    std::fs::write("bench_out/fig2_f32_94.csv", report::figure_csv(&fig)).ok();
    std::fs::write(
        "bench_out/table1_f32.md",
        report::speedup_markdown("Table 1 — single precision", &table),
    )
    .ok();
    eprintln!("wrote bench_out/fig2_f32_94.csv, bench_out/table1_f32.md");
}
