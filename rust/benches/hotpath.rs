//! Bench: L3 hot-path wall-clock — CPU engines on this host (the §Perf
//! iteration target), the batch-width sweep for the blocked SpMM path,
//! the `EHYB_THREADS` sweep for the partition-parallel walk, plus PJRT
//! SpMV latency when artifacts exist. `cargo bench --bench hotpath`.

use ehyb::harness::runner;
use ehyb::preprocess::{EhybPlan, PreprocessConfig};
use ehyb::spmv::SpmvEngine;
use ehyb::BatchBuf;
use ehyb::sparse::gen::{poisson3d, unstructured_mesh};
use ehyb::util::timer::bench_secs;
use ehyb::util::par;
use std::time::Duration;

fn main() {
    let cases: Vec<(&str, ehyb::sparse::csr::Csr<f64>)> = vec![
        ("poisson3d-44 (85k, stencil)", poisson3d(44, 44, 44)),
        ("unstructured-300 (90k, irregular)", unstructured_mesh(300, 300, 0.5, 42)),
    ];
    for (label, m) in &cases {
        println!("== {label}: n={} nnz={} ==", m.nrows(), m.nnz());
        let cfg = PreprocessConfig::default();
        match runner::bench_cpu_engines(m, &cfg) {
            Ok(rows) => {
                for (name, gflops) in rows {
                    println!("  {name:>15}: {gflops:7.3} GFLOPS (cpu wallclock)");
                }
            }
            Err(e) => println!("  failed: {e:#}"),
        }
        // Hot-loop detail: the EHYB engine's new-order path (the solver's
        // inner loop, no permutation overhead).
        let plan = EhybPlan::build(m, &cfg).unwrap();
        let engine = ehyb::spmv::ehyb_cpu::EhybCpu::new(&plan);
        let xp = vec![1.0f64; plan.matrix.padded_rows()];
        let mut yp = vec![0.0f64; plan.matrix.padded_rows()];
        // §Perf before/after: GPU-order baseline vs CPU-optimized loop.
        let secs_lane = bench_secs(
            || engine.spmv_new_order_lane_major(&xp, &mut yp),
            5,
            Duration::from_millis(300),
        );
        let secs = bench_secs(|| engine.spmv_new_order(&xp, &mut yp), 5, Duration::from_millis(300));
        println!(
            "  ehyb hot loop lane-major (before): {:.3} ms = {:.3} GFLOPS",
            secs_lane * 1e3,
            ehyb::spmv::gflops(plan.matrix.nnz(), secs_lane)
        );
        println!(
            "  ehyb hot loop k-outer    (after) : {:.3} ms = {:.3} GFLOPS ({:.2}x)",
            secs * 1e3,
            ehyb::spmv::gflops(plan.matrix.nnz(), secs),
            secs_lane / secs
        );
        // Memory-bound roofline check for this host: bytes touched/SpMV.
        let bytes = plan.matrix.bytes() + 2 * 8 * plan.matrix.padded_rows();
        println!(
            "  format bytes/SpMV = {} ({:.2} GB/s effective)",
            bytes,
            bytes as f64 / secs / 1e9
        );

        // Threads sweep: serial kernel vs partition-parallel walk
        // (set EHYB_THREADS to pin; the override below sweeps 1 vs all).
        let pinned_t = par::num_threads(); // honours EHYB_THREADS
        let max_t = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
        println!("  threads sweep (partition-parallel ELL walk, single vector):");
        let mut sweep = vec![1usize];
        if max_t > 1 {
            sweep.push(max_t);
        }
        let mut secs_t1 = secs;
        for &t in &sweep {
            par::set_num_threads(t);
            let secs_par = bench_secs(
                || engine.spmv_new_order_parallel(&xp, &mut yp),
                5,
                Duration::from_millis(300),
            );
            if t == 1 {
                secs_t1 = secs_par;
            }
            println!(
                "    threads={t:>2}: {:.3} ms = {:.3} GFLOPS ({:.2}x vs 1 thread)",
                secs_par * 1e3,
                ehyb::spmv::gflops(plan.matrix.nnz(), secs_par),
                secs_t1 / secs_par
            );
        }
        par::set_num_threads(pinned_t);

        // Batch-width sweep: one fused spmv_batch (blocked SpMM over
        // contiguous VecBatch views) vs the same B vectors through
        // repeated single-vector spmv calls.
        println!("  batch-width sweep (fused spmv_batch vs B sequential spmv):");
        let n = m.nrows();
        let mut y_seq = vec![0.0f64; n];
        for &bw in &[1usize, 2, 4, 8, 16] {
            let mut xs = BatchBuf::<f64>::zeros(n, bw);
            for t in 0..bw {
                for i in 0..n {
                    xs.col_mut(t)[i] = ((i * 7 + t * 13) % 17) as f64 * 0.25 - 2.0;
                }
            }
            let mut ys = BatchBuf::<f64>::zeros(n, bw);
            let secs_fused = bench_secs(
                || {
                    let mut ysv = ys.view_mut();
                    engine.spmv_batch(xs.view(), &mut ysv)
                },
                3,
                Duration::from_millis(200),
            );
            let secs_seq = bench_secs(
                || {
                    for t in 0..bw {
                        engine.spmv(xs.col(t), &mut y_seq);
                    }
                },
                3,
                Duration::from_millis(200),
            );
            let flops = 2.0 * (plan.matrix.nnz() * bw) as f64;
            println!(
                "    B={bw:>2}: fused {:8.3} GFLOPS vs sequential {:8.3} GFLOPS ({:.2}x)",
                flops / secs_fused / 1e9,
                flops / secs_seq / 1e9,
                secs_seq / secs_fused
            );
        }
    }

    // PJRT latency (bucketed shapes).
    if let Ok(rt) = ehyb::runtime::PjrtRuntime::new("artifacts") {
        let m = poisson3d::<f64>(40, 40, 40);
        let cfg = PreprocessConfig { vec_size_override: Some(512), ..Default::default() };
        let plan = EhybPlan::build(&m, &cfg).unwrap();
        let engine = rt.spmv_engine(&plan.matrix).unwrap();
        let xp = vec![1.0f64; engine.bucket.spec.n()];
        let t0 = std::time::Instant::now();
        let mut reps = 0u32;
        while t0.elapsed() < Duration::from_secs(3) {
            let _ = engine.spmv_new_order(&xp).unwrap();
            reps += 1;
        }
        let secs = t0.elapsed().as_secs_f64() / reps as f64;
        println!(
            "== PJRT (solver bucket, n=65536): {:.2} ms/SpMV over {} reps (interpret-mode Pallas on CPU) ==",
            secs * 1e3,
            reps
        );
    } else {
        println!("== PJRT skipped (no artifacts) ==");
    }
}
