//! Bench: L3 hot-path wall-clock — CPU engines on this host (the §Perf
//! iteration target), the batch-width sweep for the blocked SpMM path,
//! the `EHYB_THREADS` sweep for the partition-parallel walk, the
//! row-sharded engine sweep, plus PJRT SpMV latency when artifacts
//! exist. `cargo bench --bench hotpath`.
//!
//! Flags (after `--`):
//!   --smoke       CI-sized matrices + short reps (the bench-smoke job)
//!   --out PATH    write the engine sweeps as deterministic JSON
//!                 (`harness::report::bench_json`; defaults to
//!                 `BENCH_ci.json` under --smoke)

use ehyb::harness::report::{bench_json, BenchCase};
use ehyb::harness::runner;
use ehyb::preprocess::{EhybPlan, PreprocessConfig};
use ehyb::spmv::SpmvEngine;
use ehyb::BatchBuf;
use ehyb::sparse::gen::{poisson3d, unstructured_mesh};
use ehyb::util::timer::bench_secs;
use ehyb::util::par;
use ehyb::{EngineKind, ShardSpec, SpmvContext};
use std::time::Duration;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let out_path = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1).cloned())
        .or_else(|| smoke.then(|| "BENCH_ci.json".to_string()));

    let cases: Vec<(&str, ehyb::sparse::csr::Csr<f64>)> = if smoke {
        vec![
            ("poisson3d-16 (4k, stencil)", poisson3d(16, 16, 16)),
            ("unstructured-64 (4k, irregular)", unstructured_mesh(64, 64, 0.5, 42)),
        ]
    } else {
        vec![
            ("poisson3d-44 (85k, stencil)", poisson3d(44, 44, 44)),
            ("unstructured-300 (90k, irregular)", unstructured_mesh(300, 300, 0.5, 42)),
        ]
    };
    let (reps, rep_ms) = if smoke { (2, 20) } else { (5, 300) };
    let mut json_cases: Vec<BenchCase> = Vec::new();
    for (label, m) in &cases {
        println!("== {label}: n={} nnz={} ==", m.nrows(), m.nnz());
        let cfg = PreprocessConfig::default();
        let mut engine_rows: Vec<(String, f64)> = Vec::new();
        match runner::bench_cpu_engines(m, &cfg) {
            Ok(rows) => {
                for (name, gflops) in rows {
                    println!("  {name:>15}: {gflops:7.3} GFLOPS (cpu wallclock)");
                    engine_rows.push((name, gflops));
                }
            }
            Err(e) => println!("  failed: {e:#}"),
        }

        // Row-sharded engine (ISSUE 4): unsharded vs one-shard-per-core
        // fan-out of the same kind.
        for kind in [EngineKind::Ehyb, EngineKind::CsrScalar] {
            let threads = par::num_threads();
            let ks = if threads > 1 { vec![1usize, threads] } else { vec![1usize] };
            for k in ks {
                if k == 1 && kind == EngineKind::CsrScalar {
                    continue; // csr-scalar k=1 == the unsharded row above
                }
                let ctx = SpmvContext::builder(m.clone())
                    .engine(kind)
                    .config(cfg.clone())
                    .shards(ShardSpec::Count(k))
                    .build()
                    .expect("sharded build");
                let x = vec![1.0f64; m.ncols()];
                let mut y = vec![0.0f64; m.nrows()];
                let e = ctx.engine();
                let secs = bench_secs(|| e.spmv(&x, &mut y), reps, Duration::from_millis(rep_ms));
                let gf = ehyb::spmv::gflops(m.nnz(), secs);
                let name = format!("sharded{k}-{}", kind.name());
                println!("  {name:>15}: {gf:7.3} GFLOPS (K={k} row shards)");
                engine_rows.push((name, gf));
            }
        }
        // Reorder on/off sweep (ISSUE 5): the same EHYB pipeline with a
        // locality-aware global ordering applied ahead of it. Captured
        // in BENCH_ci.json so the perf trajectory tracks the reorder
        // win per commit.
        for (tag, spec) in [
            ("off", ehyb::ReorderSpec::None),
            ("rcm", ehyb::ReorderSpec::Rcm),
            ("partrank", ehyb::ReorderSpec::PartitionRank { k: 0 }),
        ] {
            let ctx = SpmvContext::builder(m.clone())
                .engine(EngineKind::Ehyb)
                .config(cfg.clone())
                .reorder(spec)
                .build()
                .expect("reordered build");
            let x = vec![1.0f64; m.ncols()];
            let mut y = vec![0.0f64; m.nrows()];
            let e = ctx.engine();
            let secs = bench_secs(|| e.spmv(&x, &mut y), reps, Duration::from_millis(rep_ms));
            let gf = ehyb::spmv::gflops(m.nnz(), secs);
            let name = format!("ehyb-reorder-{tag}");
            let band = ctx.reordering().map_or_else(
                || "natural".to_string(),
                |r| format!("bandwidth {} -> {}", r.before.bandwidth, r.after.bandwidth),
            );
            println!("  {name:>20}: {gf:7.3} GFLOPS ({band})");
            engine_rows.push((name, gf));
        }
        // Predicted-vs-measured (ISSUE 7): the replayed traffic
        // simulator's hit-aware GFLOPS land in BENCH_ci.json next to
        // the measured rows, so prediction drift is visible per commit.
        if smoke {
            let dev = ehyb::gpu::GpuDevice::v100();
            for kind in [EngineKind::Ehyb, EngineKind::CsrVector] {
                let report = if kind == EngineKind::Ehyb {
                    let plan = EhybPlan::build(m, &cfg).expect("ehyb plan");
                    ehyb::traffic::ehyb_traffic(&plan.matrix, &dev)
                } else {
                    ehyb::traffic::baseline_traffic(kind, m, &dev)
                };
                let name = format!("traffic-predicted-{}", kind.name());
                println!(
                    "  {name:>22}: {:7.3} GFLOPS (simulated V100 replay)",
                    report.gflops()
                );
                engine_rows.push((name, report.gflops()));
            }
            // Observed-vs-predicted (ISSUE 10): the profiled engines'
            // observed bytes/lane and their relative drift against the
            // replay land in BENCH_ci.json; scripts/bench_check.py
            // hard-fails the smoke job when a drift-* row exceeds the
            // 15% bound.
            if ehyb::profile::enabled() {
                for kind in [EngineKind::Ehyb, EngineKind::CsrVector] {
                    let mut ctx = SpmvContext::builder(m.clone())
                        .engine(kind)
                        .config(cfg.clone())
                        .build()
                        .expect("profiled build");
                    let x = vec![1.0f64; m.ncols()];
                    let mut y = vec![0.0f64; m.nrows()];
                    for _ in 0..3 {
                        ctx.engine().spmv(&x, &mut y);
                    }
                    let p = ctx.profile().expect("profiled engine records");
                    let d = ctx.observe_drift().expect("unsharded context replays");
                    let name = format!("observed-bytes-{}", kind.name());
                    println!(
                        "  {name:>24}: {:.0} bytes/lane (replay predicts {:.0})",
                        p.bytes_per_lane(),
                        d.predicted_bytes
                    );
                    engine_rows.push((name, p.bytes_per_lane()));
                    let name = format!("drift-{}", kind.name());
                    println!("  {name:>24}: {:.4} rel (bound {:.2})", d.stamp(), d.threshold);
                    engine_rows.push((name, d.stamp()));
                }
            }
        }
        // Scalar-vs-SIMD twins (ISSUE 9): both legs of every rewritten
        // kernel timed in one process, whichever leg the `simd` feature
        // routes the plain entry points to. scripts/bench_check.py
        // hard-fails the bench-smoke job if the EHYB simd rows trail
        // their scalar twins.
        {
            let dur = Duration::from_millis(rep_ms);
            let plan = EhybPlan::build(m, &cfg).expect("ehyb plan");
            let engine = ehyb::spmv::ehyb_cpu::EhybCpu::new(&plan);
            let padded = plan.matrix.padded_rows();
            let nnz = plan.matrix.nnz();
            let xp = vec![1.0f64; padded];
            let mut yp = vec![0.0f64; padded];
            let secs = bench_secs(|| engine.spmv_new_order_scalar(&xp, &mut yp), reps, dur);
            let gf_s = ehyb::spmv::gflops(nnz, secs);
            let secs = bench_secs(|| engine.spmv_new_order_simd(&xp, &mut yp), reps, dur);
            let gf_v = ehyb::spmv::gflops(nnz, secs);
            println!("  ehyb-ellwalk scalar {gf_s:7.3} vs simd {gf_v:7.3} GFLOPS");
            engine_rows.push(("ehyb-ellwalk-scalar".to_string(), gf_s));
            engine_rows.push(("ehyb-ellwalk-simd".to_string(), gf_v));
            // Register-blocked SpMM, 4 vectors wide.
            let xs: Vec<Vec<f64>> = (0..4)
                .map(|t| {
                    (0..padded).map(|i| ((i * 5 + t * 11 + 1) % 17) as f64 * 0.25 - 2.0).collect()
                })
                .collect();
            let xrefs: Vec<&[f64]> = xs.iter().map(|v| v.as_slice()).collect();
            let mut ys: Vec<Vec<f64>> = (0..4).map(|_| vec![0.0f64; padded]).collect();
            for simd in [false, true] {
                let secs = bench_secs(
                    || {
                        let mut yrefs: Vec<&mut [f64]> =
                            ys.iter_mut().map(|v| v.as_mut_slice()).collect();
                        engine.spmm_new_order_with(&xrefs, &mut yrefs, simd);
                    },
                    reps,
                    dur,
                );
                let gf = 2.0 * (nnz * 4) as f64 / secs / 1e9;
                let name = format!("ehyb-spmm4-{}", if simd { "simd" } else { "scalar" });
                println!("  {name:>20}: {gf:7.3} GFLOPS");
                engine_rows.push((name, gf));
            }
            // Baseline-engine twins via their explicit legs.
            let x = vec![1.0f64; m.ncols()];
            let mut y = vec![0.0f64; m.nrows()];
            let sell = ehyb::spmv::sellp::SellPEngine::new(m);
            let elle = ehyb::spmv::ell::EllEngine::new(m);
            let hybe = ehyb::spmv::hyb::HybEngine::new(m);
            let alg1 = ehyb::spmv::csr_vector::CsrVector::new(m);
            let c5 = ehyb::spmv::csr5::Csr5Like::new(m);
            let nnz_m = m.nnz();
            let mut run = |name: &str, rows: &mut Vec<(String, f64)>, f: &mut dyn FnMut()| {
                let secs = bench_secs(|| f(), reps, dur);
                let gf = ehyb::spmv::gflops(nnz_m, secs);
                println!("  {name:>20}: {gf:7.3} GFLOPS");
                rows.push((name.to_string(), gf));
            };
            run("sellp-scalar", &mut engine_rows, &mut || sell.spmv_scalar(&x, &mut y));
            run("sellp-simd", &mut engine_rows, &mut || sell.spmv_simd(&x, &mut y));
            run("ell-scalar", &mut engine_rows, &mut || elle.spmv_scalar(&x, &mut y));
            run("ell-simd", &mut engine_rows, &mut || elle.spmv_simd(&x, &mut y));
            run("hyb-scalar", &mut engine_rows, &mut || hybe.spmv_scalar(&x, &mut y));
            run("hyb-simd", &mut engine_rows, &mut || hybe.spmv_simd(&x, &mut y));
            run("alg1-scalar", &mut engine_rows, &mut || alg1.spmv_scalar(&x, &mut y));
            run("alg1-simd", &mut engine_rows, &mut || alg1.spmv_simd(&x, &mut y));
            run("csr5-scalar", &mut engine_rows, &mut || c5.spmv_scalar(&x, &mut y));
            run("csr5-simd", &mut engine_rows, &mut || c5.spmv_simd(&x, &mut y));
            // Gather-fusion on/off: the 0.9 single-gather-per-side
            // adapter vs the 0.8 two-pass permute route, same kernel.
            use std::sync::Arc;
            let r =
                Arc::new(ehyb::Reordering::compute(m, ehyb::ReorderSpec::Rcm).expect("rcm"));
            let pm = r.apply(m);
            let rplan = EhybPlan::build(&pm, &cfg).expect("reordered plan");
            let inner: Arc<dyn SpmvEngine<f64>> =
                Arc::new(ehyb::spmv::ehyb_cpu::EhybCpu::new(&rplan));
            let fused = ehyb::reorder::ReorderedEngine::new(inner.clone(), r.clone());
            let two = ehyb::reorder::ReorderedEngine::with_fusion(inner, r, false);
            run("ehyb-rcm-fused", &mut engine_rows, &mut || fused.spmv(&x, &mut y));
            run("ehyb-rcm-twopass", &mut engine_rows, &mut || two.spmv(&x, &mut y));
        }
        json_cases.push(BenchCase {
            matrix: label.split_whitespace().next().unwrap_or(label).to_string(),
            n: m.nrows(),
            nnz: m.nnz(),
            engines: engine_rows,
        });
        if smoke {
            continue; // smoke mode skips the long sweeps below
        }
        // Hot-loop detail: the EHYB engine's new-order path (the solver's
        // inner loop, no permutation overhead).
        let plan = EhybPlan::build(m, &cfg).unwrap();
        let engine = ehyb::spmv::ehyb_cpu::EhybCpu::new(&plan);
        let xp = vec![1.0f64; plan.matrix.padded_rows()];
        let mut yp = vec![0.0f64; plan.matrix.padded_rows()];
        // §Perf before/after: GPU-order baseline vs CPU-optimized loop.
        let secs_lane = bench_secs(
            || engine.spmv_new_order_lane_major(&xp, &mut yp),
            5,
            Duration::from_millis(300),
        );
        let secs =
            bench_secs(|| engine.spmv_new_order(&xp, &mut yp), 5, Duration::from_millis(300));
        println!(
            "  ehyb hot loop lane-major (before): {:.3} ms = {:.3} GFLOPS",
            secs_lane * 1e3,
            ehyb::spmv::gflops(plan.matrix.nnz(), secs_lane)
        );
        println!(
            "  ehyb hot loop k-outer    (after) : {:.3} ms = {:.3} GFLOPS ({:.2}x)",
            secs * 1e3,
            ehyb::spmv::gflops(plan.matrix.nnz(), secs),
            secs_lane / secs
        );
        // Memory-bound roofline check for this host: bytes touched/SpMV.
        let bytes = plan.matrix.bytes() + 2 * 8 * plan.matrix.padded_rows();
        println!(
            "  format bytes/SpMV = {} ({:.2} GB/s effective)",
            bytes,
            bytes as f64 / secs / 1e9
        );

        // Threads sweep: serial kernel vs partition-parallel walk
        // (set EHYB_THREADS to pin; the override below sweeps 1 vs all).
        let pinned_t = par::num_threads(); // honours EHYB_THREADS
        let max_t = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
        println!("  threads sweep (partition-parallel ELL walk, single vector):");
        let mut sweep = vec![1usize];
        if max_t > 1 {
            sweep.push(max_t);
        }
        let mut secs_t1 = secs;
        for &t in &sweep {
            par::set_num_threads(t);
            let secs_par = bench_secs(
                || engine.spmv_new_order_parallel(&xp, &mut yp),
                5,
                Duration::from_millis(300),
            );
            if t == 1 {
                secs_t1 = secs_par;
            }
            println!(
                "    threads={t:>2}: {:.3} ms = {:.3} GFLOPS ({:.2}x vs 1 thread)",
                secs_par * 1e3,
                ehyb::spmv::gflops(plan.matrix.nnz(), secs_par),
                secs_t1 / secs_par
            );
        }
        par::set_num_threads(pinned_t);

        // Batch-width sweep: one fused spmv_batch (blocked SpMM over
        // contiguous VecBatch views) vs the same B vectors through
        // repeated single-vector spmv calls.
        println!("  batch-width sweep (fused spmv_batch vs B sequential spmv):");
        let n = m.nrows();
        let mut y_seq = vec![0.0f64; n];
        for &bw in &[1usize, 2, 4, 8, 16] {
            let mut xs = BatchBuf::<f64>::zeros(n, bw);
            for t in 0..bw {
                for i in 0..n {
                    xs.col_mut(t)[i] = ((i * 7 + t * 13) % 17) as f64 * 0.25 - 2.0;
                }
            }
            let mut ys = BatchBuf::<f64>::zeros(n, bw);
            let secs_fused = bench_secs(
                || {
                    let mut ysv = ys.view_mut();
                    engine.spmv_batch(xs.view(), &mut ysv)
                },
                3,
                Duration::from_millis(200),
            );
            let secs_seq = bench_secs(
                || {
                    for t in 0..bw {
                        engine.spmv(xs.col(t), &mut y_seq);
                    }
                },
                3,
                Duration::from_millis(200),
            );
            let flops = 2.0 * (plan.matrix.nnz() * bw) as f64;
            println!(
                "    B={bw:>2}: fused {:8.3} GFLOPS vs sequential {:8.3} GFLOPS ({:.2}x)",
                flops / secs_fused / 1e9,
                flops / secs_seq / 1e9,
                secs_seq / secs_fused
            );
        }
    }

    if let Some(path) = &out_path {
        let label = if smoke { "ci-smoke" } else { "hotpath" };
        let mut j = bench_json(label, &json_cases);
        if smoke {
            // Attach a deterministic telemetry snapshot (ISSUE 8): a
            // fake-clock instrumented build + served round-trips on the
            // first smoke matrix, exported under the "telemetry" key so
            // the per-commit BENCH_ci.json artifact also carries the
            // pipeline's span/metric decomposition.
            let m = cases[0].1.clone();
            let ctx = SpmvContext::builder(m)
                .engine(EngineKind::Ehyb)
                .telemetry(ehyb::Telemetry::with_fake_clock())
                .build()
                .expect("telemetry smoke build");
            let svc = ctx.serve(4).expect("telemetry smoke serve");
            let client = svc.client();
            for t in 0..3usize {
                let x: Vec<f64> =
                    (0..ctx.nrows()).map(|i| ((i * 3 + t * 7) % 13) as f64 * 0.5 - 3.0).collect();
                client.spmv(x).expect("telemetry smoke round trip");
            }
            drop(svc);
            if let ehyb::runtime::json::Json::Obj(map) = &mut j {
                map.insert("telemetry".to_string(), ctx.telemetry_snapshot().to_json());
            }
        }
        std::fs::write(path, j.dump()).expect("write bench JSON");
        println!("wrote {path} ({} cases)", json_cases.len());
    }
    if smoke {
        return; // CI smoke stops before the PJRT probe
    }

    // PJRT latency (bucketed shapes).
    if let Ok(rt) = ehyb::runtime::PjrtRuntime::new("artifacts") {
        let m = poisson3d::<f64>(40, 40, 40);
        let cfg = PreprocessConfig { vec_size_override: Some(512), ..Default::default() };
        let plan = EhybPlan::build(&m, &cfg).unwrap();
        let engine = rt.spmv_engine(&plan.matrix).unwrap();
        let xp = vec![1.0f64; engine.bucket.spec.n()];
        let t0 = std::time::Instant::now();
        let mut reps = 0u32;
        while t0.elapsed() < Duration::from_secs(3) {
            let _ = engine.spmv_new_order(&xp).unwrap();
            reps += 1;
        }
        let secs = t0.elapsed().as_secs_f64() / reps as f64;
        println!(
            "== PJRT (solver bucket, n=65536): {:.2} ms/SpMV over {} reps (interpret-mode Pallas on CPU) ==",
            secs * 1e3,
            reps
        );
    } else {
        println!("== PJRT skipped (no artifacts) ==");
    }
}
