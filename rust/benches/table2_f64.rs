//! Bench: regenerate **Table 2** (double-precision speedups; yaSpMV
//! excluded — no f64 support, paper §5.2) and the **Figure 4** series,
//! plus **Figures 3/5** (16 commonly-tested matrices, both precisions).
//! Run with `cargo bench --bench table2_f64`.

use ehyb::gpu::GpuDevice;
use ehyb::harness::{report, runner, suite, tables};
use ehyb::preprocess::PreprocessConfig;
use ehyb::sparse::csr::Csr;

fn sweep<S: ehyb::runtime::XlaScalar>(
    specs: &[suite::MatrixSpec],
    dev: &GpuDevice,
    tag: &str,
) -> Vec<runner::MatrixRun> {
    let mut runs = Vec::new();
    for (i, spec) in specs.iter().enumerate() {
        let m: Csr<S> = spec.build().cast();
        match runner::run_matrix(&spec.name, spec.category, &m, &PreprocessConfig::default(), dev)
        {
            Ok(r) => {
                eprintln!("[{tag} {}/{}] {}", i + 1, specs.len(), spec.name);
                runs.push(r);
            }
            Err(e) => eprintln!("[{tag} {}/{}] {} failed: {e:#}", i + 1, specs.len(), spec.name),
        }
    }
    runs
}

fn main() {
    let scale = suite::Scale::from_env();
    let dev = GpuDevice::v100();
    std::fs::create_dir_all("bench_out").ok();

    // Table 2 + Figure 4: 94 matrices, f64.
    let specs94 = suite::suite94(scale);
    let runs64 = sweep::<f64>(&specs94, &dev, "94/f64");
    let table = tables::speedup_table::<f64>(&runs64);
    let title2 = "Table 2 — EHYB speedup, double precision (simulated V100)";
    println!("{}", report::speedup_markdown(title2, &table));
    let fig4 = tables::figure_series::<f64>(&runs64);
    println!("Figure 4 summary:\n{}", report::figure_summary(&fig4));
    std::fs::write("bench_out/fig4_f64_94.csv", report::figure_csv(&fig4)).ok();
    std::fs::write(
        "bench_out/table2_f64.md",
        report::speedup_markdown("Table 2 — double precision", &table),
    )
    .ok();

    // Figures 3 and 5: the 16 commonly tested matrices.
    let specs16 = suite::suite16(scale);
    let runs16_32 = sweep::<f32>(&specs16, &dev, "16/f32");
    let runs16_64 = sweep::<f64>(&specs16, &dev, "16/f64");
    let fig3 = tables::figure_series::<f32>(&runs16_32);
    let fig5 = tables::figure_series::<f64>(&runs16_64);
    println!("Figure 3 summary:\n{}", report::figure_summary(&fig3));
    println!("Figure 5 summary:\n{}", report::figure_summary(&fig5));
    std::fs::write("bench_out/fig3_f32_16.csv", report::figure_csv(&fig3)).ok();
    std::fs::write("bench_out/fig5_f64_16.csv", report::figure_csv(&fig5)).ok();
    eprintln!("wrote bench_out/{{table2_f64.md,fig4_f64_94.csv,fig3_f32_16.csv,fig5_f64_16.csv}}");
}
