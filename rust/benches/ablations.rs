//! Bench: DESIGN.md §7 ablations on a representative unstructured-mesh
//! matrix — isolates each of the paper's design choices:
//! explicit cache, u16 columns, partitioner quality, descending-nnz
//! sort, and the VecSize (equation 1-2) sweep.
//! `cargo bench --bench ablations`.

use ehyb::gpu::GpuDevice;
use ehyb::harness::{ablation, report, suite};
use ehyb::preprocess::PreprocessConfig;

fn main() {
    let scale = suite::Scale::from_env();
    let dim = match scale {
        suite::Scale::Tiny => 48,
        suite::Scale::Small => 200,
        suite::Scale::Full => 600,
    };
    let m = ehyb::sparse::gen::unstructured_mesh::<f64>(dim, dim, 0.5, 42);
    let cfg = PreprocessConfig::default();
    let dev = GpuDevice::v100();
    let mut out = String::new();

    let rows = ablation::cache_and_cols(&m, &cfg, &dev).unwrap();
    out += &report::ablation_markdown("§7.1+7.2 Explicit cache × column width", &rows);
    let rows = ablation::partitioner_quality(&m, &cfg, &dev).unwrap();
    out += &report::ablation_markdown("§7.3 Partitioner quality", &rows);
    let rows = ablation::sort_ablation(&m, &cfg, &dev).unwrap();
    out += &report::ablation_markdown("§7.4 Descending-nnz reorder", &rows);
    let rows =
        ablation::vecsize_sweep(&m, &cfg, &dev, &[64, 128, 256, 512, 1024, 2048, 4096]).unwrap();
    out += &report::ablation_markdown("§7.5 VecSize sweep (equations 1-2)", &rows);

    println!("{out}");
    std::fs::create_dir_all("bench_out").ok();
    std::fs::write("bench_out/ablations.md", out).ok();
    eprintln!("wrote bench_out/ablations.md");
}
