//! Bench: autotuner payoff and cost — default vs heuristic-tuned vs
//! measured-tuned EHYB plans (CPU wall-clock GFLOPS), the one-time
//! search cost at each level, and the plan-cache warm-start time.
//! `cargo bench --bench autotune`.

use ehyb::autotune::TuneLevel;
use ehyb::preprocess::PreprocessConfig;
use ehyb::sparse::gen::{circuit, poisson3d, unstructured_mesh};
use ehyb::spmv::SpmvEngine;
use ehyb::util::timer::bench_secs;
use ehyb::util::Timer;
use ehyb::{EngineKind, SpmvContext};
use std::time::Duration;

fn engine_gflops(ctx: &SpmvContext<f64>) -> f64 {
    let n = ctx.nrows();
    let x = vec![1.0f64; n];
    let mut y = vec![0.0f64; n];
    let engine = ctx.engine();
    let secs = bench_secs(|| engine.spmv(&x, &mut y), 5, Duration::from_millis(200));
    ehyb::spmv::gflops(ctx.nnz(), secs)
}

fn main() {
    let cases: Vec<(&str, ehyb::sparse::csr::Csr<f64>)> = vec![
        ("poisson3d-32 (33k, stencil)", poisson3d(32, 32, 32)),
        ("unstructured-200 (40k, irregular)", unstructured_mesh(200, 200, 0.5, 42)),
        ("circuit-30k (hub rows)", circuit(30_000, 4, 0.001, 7)),
    ];
    for (label, m) in &cases {
        println!("== {label}: n={} nnz={} ==", m.nrows(), m.nnz());
        let cfg = PreprocessConfig::default();
        let variants: [(&str, Option<TuneLevel>); 3] = [
            ("default", None),
            ("heuristic", Some(TuneLevel::Heuristic)),
            ("measured", Some(TuneLevel::Measured { budget: Duration::from_millis(500) })),
        ];
        for (name, level) in variants {
            let t = Timer::start();
            // Fresh search per variant; never touch the user's
            // EHYB_TUNE_DIR cache from a benchmark.
            let mut b = SpmvContext::builder(m.clone())
                .engine(EngineKind::Ehyb)
                .config(cfg.clone())
                .no_plan_cache();
            if let Some(level) = level {
                b = b.tune(level);
            }
            let ctx = match b.build() {
                Ok(ctx) => ctx,
                Err(e) => {
                    println!("  {name:>9}: build failed: {e:#}");
                    continue;
                }
            };
            let build_secs = t.elapsed_secs();
            let gf = engine_gflops(&ctx);
            let plan = ctx.plan().expect("EHYB context carries a plan");
            let knobs = format!(
                "vec_size={} h={} cutoff={:?}",
                plan.matrix.vec_size,
                plan.matrix.slice_height,
                ctx.config().ell_width_cutoff
            );
            match ctx.tuned() {
                Some(tp) => println!(
                    "  {name:>9}: {gf:7.3} GFLOPS  ({knobs}; search+build {build_secs:.3}s; \
                     score {:.3e}s vs default {:.3e}s)",
                    tp.score_secs, tp.default_score_secs
                ),
                None => println!("  {name:>9}: {gf:7.3} GFLOPS  ({knobs}; build {build_secs:.3}s)"),
            }
        }
        // Plan-cache warm start: persist the measured winner, then time
        // a rebuild that loads it instead of searching.
        let dir = std::env::temp_dir().join(format!("ehyb-autotune-bench-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let cold = SpmvContext::builder(m.clone())
            .engine(EngineKind::Ehyb)
            .config(cfg.clone())
            .tune(TuneLevel::Measured { budget: Duration::from_millis(500) })
            .plan_cache(&dir);
        let t = Timer::start();
        let ok = cold.build().is_ok();
        let cold_secs = t.elapsed_secs();
        if ok {
            let t = Timer::start();
            let _warm = SpmvContext::builder(m.clone())
                .engine(EngineKind::Ehyb)
                .config(cfg.clone())
                .tune(TuneLevel::Measured { budget: Duration::from_millis(500) })
                .plan_cache(&dir)
                .build()
                .unwrap();
            let warm_secs = t.elapsed_secs();
            println!(
                "  plan cache: cold tune+build {cold_secs:.3}s -> warm reload {warm_secs:.3}s \
                 ({:.1}x faster restart)",
                cold_secs / warm_secs.max(1e-9)
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
