#!/usr/bin/env python3
"""Perf-regression gate for the bench-smoke CI job.

Compares a freshly produced ``BENCH_ci.json`` (schema ``ehyb-bench-v1``,
written by ``cargo bench --bench hotpath -- --smoke``) against the
committed ``BENCH_baseline.json`` and enforces two kinds of checks:

1. **Cross-run regression** — per (matrix, engine-row) pair present in
   both files, the current GFLOPS must not fall more than
   ``MAX_REGRESSION`` below the baseline. This is a *hard* failure only
   when the baseline declares ``"provenance": "measured"`` (i.e. it was
   recorded on the same class of CI runner). A baseline marked
   ``"estimated"`` produces advisory warnings instead, because absolute
   numbers from a different host class would gate on noise. Promote the
   baseline by re-recording it from a CI artifact and flipping the
   provenance field.

2. **Within-run scalar-vs-simd pairs** — always hard, host-independent:
   both legs ran seconds apart in the same process, so the simd row of
   each ``PAIR_PREFIXES`` entry must reach at least ``PAIR_TOLERANCE``
   of its scalar twin. This is the gate that catches a SIMD leg
   silently degrading into (or below) the scalar walk.

3. **Within-run model drift** — always hard, host-independent: every
   ``drift-<engine>`` row in the current run holds the worst relative
   gap between the bytes that engine *observably* moved and what the
   traffic simulator predicted for the same plan, and must stay at or
   under ``DRIFT_BOUND``. A failure here means the cost model the tuner
   scores with no longer describes the kernels that actually run.

Rows present in only one file (e.g. host-dependent ``sharded<K>-*``
names) are skipped and counted, never failed: the smoke sweep grows
over time and the baseline must not block adding rows.
``drift-*`` and ``observed-bytes-*`` rows hold fractions and byte
counts, not GFLOPS, so they are excluded from the cross-run
regression comparison.

Usage: ``bench_check.py BENCH_baseline.json BENCH_ci.json``
Exit status: 0 ok, 1 hard failure, 2 usage/schema error.
"""

import json
import sys

SCHEMA = "ehyb-bench-v1"
# Hard-fail when a measured baseline row regresses by more than this.
MAX_REGRESSION = 0.25
# Within one run, a simd leg must reach this fraction of its scalar
# twin (slack for timer noise on short smoke reps).
PAIR_TOLERANCE = 0.98
# Engine-row prefixes whose `<prefix>-simd` must keep up with
# `<prefix>-scalar` in the same run.
PAIR_PREFIXES = ["ehyb-ellwalk", "ehyb-spmm4"]
# A drift-* row (observed-vs-simulated relative gap) past this bound
# hard-fails the run: the tuner's cost model has stopped describing
# the kernels that actually execute.
DRIFT_BOUND = 0.15
# Row prefixes that are not GFLOPS and must not enter the cross-run
# regression comparison.
NON_GFLOPS_PREFIXES = ("drift-", "observed-bytes-")


def load(path):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        sys.exit(f"bench_check: cannot read {path}: {e}")
    if doc.get("schema") != SCHEMA:
        sys.exit(f"bench_check: {path}: schema {doc.get('schema')!r} != {SCHEMA!r}")
    return doc


def rows(doc):
    """{(matrix, engine): gflops} across all cases."""
    out = {}
    for case in doc.get("cases", []):
        for name, g in case.get("gflops", {}).items():
            out[(case["matrix"], name)] = float(g)
    return out


def main():
    if len(sys.argv) != 3:
        sys.exit(__doc__)
    base_doc = load(sys.argv[1])
    cur_doc = load(sys.argv[2])
    measured = base_doc.get("provenance", "estimated") == "measured"
    base = rows(base_doc)
    cur = rows(cur_doc)

    failures, warnings, compared, skipped = [], [], 0, 0

    # 1. Cross-run regression against the committed baseline.
    for key, b in sorted(base.items()):
        if key[1].startswith(NON_GFLOPS_PREFIXES):
            continue
        if key not in cur:
            skipped += 1
            continue
        compared += 1
        c = cur[key]
        if b > 0 and c < b * (1.0 - MAX_REGRESSION):
            msg = (f"{key[0]} / {key[1]}: {c:.3f} GFLOPS is "
                   f"{100 * (1 - c / b):.1f}% below baseline {b:.3f}")
            (failures if measured else warnings).append(msg)

    # 2. Within-run simd-vs-scalar pairs (always hard).
    pair_count = 0
    for case in cur_doc.get("cases", []):
        g = case.get("gflops", {})
        for prefix in PAIR_PREFIXES:
            s, v = g.get(f"{prefix}-scalar"), g.get(f"{prefix}-simd")
            if s is None or v is None:
                failures.append(
                    f"{case['matrix']}: missing {prefix}-scalar/simd pair in current run")
                continue
            pair_count += 1
            if v < s * PAIR_TOLERANCE:
                failures.append(
                    f"{case['matrix']} / {prefix}: simd leg {v:.3f} GFLOPS trails "
                    f"scalar twin {s:.3f} (< {PAIR_TOLERANCE:.0%})")

    # 3. Within-run model drift (always hard). The bench only emits
    # drift-* rows when the profile feature is compiled in, so a
    # feature-off smoke run simply checks zero rows.
    drift_count = 0
    for (matrix, name), v in sorted(cur.items()):
        if not name.startswith("drift-"):
            continue
        drift_count += 1
        if v > DRIFT_BOUND:
            failures.append(
                f"{matrix} / {name}: observed-vs-simulated drift {v:.3f} "
                f"exceeds bound {DRIFT_BOUND}")

    prov = "measured (hard gate)" if measured else "estimated (advisory)"
    print(f"bench_check: baseline provenance {prov}; "
          f"{compared} rows compared, {skipped} baseline rows absent from current run, "
          f"{pair_count} simd pairs checked, {drift_count} drift rows checked")
    for w in warnings:
        print(f"  warn: {w}")
    for f in failures:
        print(f"  FAIL: {f}")
    if failures:
        sys.exit(1)
    print("bench_check: OK")


if __name__ == "__main__":
    main()
