//! A tour of kernel-level profiling + model-drift observability (the
//! CI profile gate runs exactly this).
//!
//! ```text
//! cargo run --release --example profile
//! ```
//!
//! 1. Run a small EHYB workload and read back the [`KernelProfile`]
//!    its hot paths recorded: per-component bytes, tile reuse, padding
//!    waste, observed GFLOPS/bandwidth.
//! 2. Diff observation against the traffic simulator's replay of the
//!    same plan ([`DriftReport`]) — at B=1 every compulsory stream
//!    must tie out exactly, so uncalibrated drift is zero.
//! 3. Probe a few engines with measured timings, least-squares-fit a
//!    host [`Calibration`], persist it through the plan store's atomic
//!    JSON, reload it, and show the calibrated drift report.
//!
//! [`KernelProfile`]: ehyb::KernelProfile
//! [`DriftReport`]: ehyb::DriftReport
//! [`Calibration`]: ehyb::Calibration

use std::time::Instant;

use ehyb::autotune::device_key;
use ehyb::harness::report;
use ehyb::preprocess::PreprocessConfig;
use ehyb::profile::CalSample;
use ehyb::sparse::gen;
use ehyb::{Calibration, EngineKind, PlanStore, SpmvContext};

fn main() -> anyhow::Result<()> {
    if !ehyb::profile::enabled() {
        println!("built without the `profile` feature; nothing to observe");
        return Ok(());
    }
    let cfg = PreprocessConfig { vec_size_override: Some(128), ..Default::default() };
    let m = gen::unstructured_mesh::<f64>(48, 48, 0.5, 9);
    let x: Vec<f64> = (0..m.ncols()).map(|i| ((i * 13 + 5) % 23) as f64 * 0.125 - 1.0).collect();

    // 1. Observe: the engines count their own data movement in the hot
    //    paths — a handful of relaxed atomic adds per call.
    let ctx = SpmvContext::builder(m.clone())
        .engine(EngineKind::Ehyb)
        .config(cfg.clone())
        .build()?;
    let mut y = vec![0.0; ctx.nrows()];
    for _ in 0..5 {
        ctx.spmv(&x, &mut y)?;
    }
    let p = ctx.profile().expect("profiled engine records");
    println!("{}", report::profile_markdown("Observed kernel profile — ehyb", &p));

    // 2. Diff: the same plan replayed through the traffic simulator.
    //    Compulsory streams tie out exactly at B=1, so the verdict is
    //    "within bounds" with zero component drift.
    let d = ctx.drift().expect("unsharded context replays its plan");
    println!("{}", report::drift_markdown("Model drift — ehyb vs traffic replay", &d));
    anyhow::ensure!(d.max_rel_drift() == 0.0, "compulsory streams must tie out: {d:?}");
    anyhow::ensure!(!d.exceeded(), "uncalibrated drift must stay within bounds");

    // 3. Calibrate: measure a few engines with different DRAM/L2/shm
    //    mixes, fit secs/byte per level, persist + reload.
    let mut samples = Vec::new();
    for kind in [EngineKind::Ehyb, EngineKind::CsrVector, EngineKind::CsrScalar, EngineKind::SellP]
    {
        let probe = SpmvContext::builder(m.clone()).engine(kind).config(cfg.clone()).build()?;
        let traffic = probe.predicted_traffic().expect("unsharded probe replays");
        let mut yp = vec![0.0; probe.nrows()];
        probe.spmv(&x, &mut yp)?; // warm
        let reps = 5;
        let t0 = Instant::now();
        for _ in 0..reps {
            probe.spmv(&x, &mut yp)?;
        }
        let secs = t0.elapsed().as_secs_f64() / reps as f64;
        println!("probe {:<11}: {:.1} us/call", kind.name(), secs * 1e6);
        samples.push(CalSample::of(&traffic, secs));
    }
    let cal = Calibration::fit(&samples).expect("4 probes give a well-posed fit");
    println!(
        "fit          : dram {:.3e} s/B, l2 {:.3e} s/B, shm {:.3e} s/B, base {:.3e} s \
         (residual {:.3})",
        cal.dram_secs_per_byte,
        cal.l2_secs_per_byte,
        cal.shm_secs_per_byte,
        cal.base_secs,
        cal.residual
    );

    let dir = std::env::temp_dir().join(format!("ehyb-example-profile-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let store = PlanStore::new(&dir);
    let key = device_key(&cfg.device);
    let path = store.save_calibration(&cal, &key, "f64")?;
    let back = store
        .load_calibration(&key, "f64")?
        .expect("just-saved calibration loads back");
    anyhow::ensure!(back == cal, "calibration round trip drifted");
    println!("persisted    : {} (round-trips bit-exact)", path.display());

    // A context built with the fit applies it wherever predicted_secs
    // is read; the drift report then judges calibrated seconds too.
    let mut calibrated = SpmvContext::builder(m)
        .engine(EngineKind::Ehyb)
        .config(cfg)
        .calibration(cal)
        .build()?;
    for _ in 0..5 {
        calibrated.spmv(&x, &mut y)?;
    }
    let dc = calibrated.observe_drift().expect("calibrated observation");
    println!(
        "calibrated   : predicted {:.1} us vs observed {:.1} us per call (stamp {:.3})",
        dc.predicted_secs * 1e6,
        dc.observed_secs * 1e6,
        dc.stamp()
    );
    anyhow::ensure!(dc.calibrated, "report must mark the calibrated leg");
    anyhow::ensure!(dc.max_rel_drift() == 0.0, "byte components still tie out");
    std::fs::remove_dir_all(&dir).ok();

    println!("ok");
    Ok(())
}
