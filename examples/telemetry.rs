//! A tour of the unified telemetry subsystem (the CI telemetry gate
//! runs exactly this).
//!
//! ```text
//! cargo run --release --example telemetry
//! ```
//!
//! 1. Build a sharded EHYB context under a **fake-clock**
//!    [`Telemetry`] handle: every stage of the build pipeline
//!    (`reorder`, `tune`, `shard.build`, the derived `ehyb.partition` /
//!    `ehyb.assemble` spans) lands in one deterministic span tree.
//! 2. Serve a few requests and run a CG solve; every request gets a
//!    trace ID at submit, and per-shard kernel spans plus solver
//!    iteration events record into the same handle.
//! 3. Snapshot once, then render that single snapshot four ways:
//!    markdown tables, the span tree, Prometheus text exposition, and
//!    deterministic JSON — and replay one request's whole story with
//!    [`TelemetrySnapshot::describe_trace`].

use ehyb::harness::report;
use ehyb::preprocess::PreprocessConfig;
use ehyb::sparse::gen;
use ehyb::{EngineKind, ShardSpec, SpmvContext, Telemetry};

fn main() -> anyhow::Result<()> {
    // 1. Build under a fake clock: timestamps are logical ticks, so two
    //    runs produce byte-identical span trees.
    let m = gen::poisson2d::<f64>(24, 24);
    let n = m.nrows();
    let ctx = SpmvContext::builder(m.clone())
        .engine(EngineKind::Ehyb)
        .config(PreprocessConfig { vec_size_override: Some(64), ..Default::default() })
        .shards(ShardSpec::Count(2))
        .telemetry(Telemetry::with_fake_clock())
        .build()?;
    println!("matrix      : n={} nnz={} shards={}", n, m.nnz(), ctx.shards());

    // 2. Serve a few round-trips (each drains as a fused batch with
    //    per-shard kernel spans), then solve.
    {
        let svc = ctx.serve(8)?;
        let client = svc.client();
        for t in 0..3 {
            let x: Vec<f64> = (0..n).map(|i| ((i * 5 + t * 3) % 11) as f64 * 0.5 - 2.0).collect();
            let y = client.spmv(x.clone())?;
            anyhow::ensure!(y.len() == n, "bad reply length");
        }
    }
    let b: Vec<f64> = (0..n).map(|i| ((i % 7) as f64) * 0.5 + 0.25).collect();
    let precond = ehyb::coordinator::Jacobi::new(ctx.matrix());
    let (_, rep) =
        ctx.solver().cg(&b, None, &precond, &ehyb::coordinator::SolverConfig::default())?;
    anyhow::ensure!(rep.converged(), "CG should converge on poisson2d");
    println!("solve       : {} {} after {} iters", rep.solver, rep.status.name(), rep.iters);

    // 3. One snapshot, four views.
    let snap = ctx.telemetry_snapshot();
    println!();
    println!("{}", report::telemetry_markdown("Telemetry tour", &snap));

    println!("--- prometheus ---");
    print!("{}", snap.to_prometheus());
    println!();

    let json = snap.to_json().dump();
    println!("--- json ({} bytes) ---", json.len());

    // Determinism: a frozen registry exports byte-identically.
    let again = ctx.telemetry_snapshot();
    anyhow::ensure!(again.to_json().dump() == json, "frozen JSON export drifted");
    anyhow::ensure!(
        again.to_prometheus() == snap.to_prometheus(),
        "frozen Prometheus export drifted"
    );
    println!("determinism : both exporters byte-identical across two snapshots");

    // Replay one served request's story from the same snapshot.
    let traces = snap.known_traces();
    anyhow::ensure!(!traces.is_empty(), "workload minted no traces");
    println!();
    println!("--- trace {} ---", traces[0]);
    print!("{}", snap.describe_trace(traces[0]));

    println!("ok");
    Ok(())
}
