//! Quickstart: the EHYB pipeline end to end on one matrix.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! 1. Generate an unstructured-mesh FEM matrix (locality hidden behind
//!    random labels — the case graph partitioning exists for).
//! 2. Preprocess: partition → reorder → sliced-ELL/ER split (paper
//!    Algorithms 1–2), report the structure EHYB got.
//! 3. SpMV three ways — CPU reference, optimized CPU engine, and the
//!    AOT-compiled XLA artifact over PJRT — and check they agree.
//! 4. Compare against every baseline on the simulated V100.

use ehyb::gpu::GpuDevice;
use ehyb::harness::runner;
use ehyb::preprocess::{EhybPlan, PreprocessConfig};
use ehyb::sparse::gen::unstructured_mesh;
use ehyb::sparse::stats::MatrixStats;
use ehyb::spmv::SpmvEngine;
use ehyb::util::check::assert_allclose;

fn main() -> anyhow::Result<()> {
    // 1. A 16k-row unstructured mesh (fits the "quickstart" bucket).
    let m = unstructured_mesh::<f64>(128, 128, 0.5, 42);
    println!("matrix: {}", MatrixStats::of(&m).oneline());

    // 2. Preprocess (vec_size matched to the quickstart artifact).
    let cfg = PreprocessConfig { vec_size_override: Some(512), ..Default::default() };
    let plan = EhybPlan::build(&m, &cfg)?;
    println!(
        "EHYB: {} partitions x {} rows; ER = {:.2}% of nnz; ELL fill = {:.3}; {:.1}% smaller than u32 cols",
        plan.matrix.num_parts,
        plan.matrix.vec_size,
        100.0 * plan.matrix.er_fraction(),
        plan.matrix.ell_fill_ratio(),
        100.0 * (1.0 - plan.matrix.bytes() as f64 / plan.matrix.bytes_u32_cols() as f64),
    );
    println!(
        "preprocessing: partition {:.3}s + reorder {:.3}s",
        plan.timings.partition_secs, plan.timings.reorder_secs
    );

    // 3. SpMV three ways.
    let n = m.nrows();
    let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
    let oracle = m.spmv_f64_oracle(&x);

    let engine = ehyb::spmv::ehyb_cpu::EhybCpu::new(&plan);
    let mut y_cpu = vec![0.0; n];
    engine.spmv(&x, &mut y_cpu);
    assert_allclose(&y_cpu, &oracle, 1e-10, 1e-10).map_err(|e| anyhow::anyhow!(e))?;
    println!("CPU EHYB engine: matches oracle");

    // Batched SpMV: 4 vectors through the blocked SpMM kernel — the
    // matrix streams once per register block instead of once per vector.
    let xs: Vec<Vec<f64>> =
        (0..4).map(|t| (0..n).map(|i| ((i * 3 + t * 7) % 13) as f64 * 0.5 - 3.0).collect()).collect();
    let xrefs: Vec<&[f64]> = xs.iter().map(|v| v.as_slice()).collect();
    let mut ys: Vec<Vec<f64>> = vec![Vec::new(); xrefs.len()];
    engine.spmv_batch(&xrefs, &mut ys);
    for (xb, yb) in xs.iter().zip(&ys) {
        assert_allclose(yb, &m.spmv_f64_oracle(xb), 1e-10, 1e-10)
            .map_err(|e| anyhow::anyhow!(e))?;
    }
    println!("CPU EHYB spmv_batch (B=4): matches oracle");

    match ehyb::runtime::PjrtRuntime::new("artifacts") {
        Ok(rt) => {
            let pjrt = rt.spmv_engine(&plan.matrix)?;
            let mut y_pjrt = vec![0.0; n];
            pjrt.spmv(&x, &mut y_pjrt)?;
            assert_allclose(&y_pjrt, &oracle, 1e-9, 1e-9).map_err(|e| anyhow::anyhow!(e))?;
            println!("PJRT ({}) via AOT artifact: matches oracle", rt.platform());
        }
        Err(e) => println!("PJRT skipped ({e}) — run `make artifacts`"),
    }

    // 4. Simulated V100 comparison.
    let run = runner::run_matrix("quickstart", "demo", &m, &cfg, &GpuDevice::v100())?;
    println!("\nsimulated V100:");
    for row in &run.rows {
        let speedup = run.gflops_of("ehyb").unwrap() / row.gflops;
        println!(
            "  {:>15}: {:7.2} GFLOPS{}",
            row.framework,
            row.gflops,
            if row.framework == "ehyb" { String::new() } else { format!("  (EHYB is {speedup:.2}x)") }
        );
    }
    Ok(())
}
