//! Quickstart: the EHYB pipeline end to end on one matrix, through the
//! [`SpmvContext`] facade.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! 1. Generate an unstructured-mesh FEM matrix (locality hidden behind
//!    random labels — the case graph partitioning exists for).
//! 2. Build the context once: partition → reorder → sliced-ELL/ER split
//!    (paper Algorithms 1–2) behind `SpmvContext::builder`, report the
//!    structure EHYB got.
//! 3. SpMV three ways — CPU reference, the context's prepared engine,
//!    and the AOT-compiled XLA artifact over PJRT — and check they agree.
//! 4. Compare against every baseline on the simulated V100.

use ehyb::gpu::GpuDevice;
use ehyb::harness::runner;
use ehyb::preprocess::PreprocessConfig;
use ehyb::sparse::gen::unstructured_mesh;
use ehyb::sparse::stats::MatrixStats;
use ehyb::util::check::assert_allclose;
use ehyb::{BatchBuf, EngineKind, SpmvContext};

fn main() -> anyhow::Result<()> {
    // 1. A 16k-row unstructured mesh (fits the "quickstart" bucket).
    let m = unstructured_mesh::<f64>(128, 128, 0.5, 42);
    println!("matrix: {}", MatrixStats::of(&m).oneline());
    let n = m.nrows();

    // 2. Build the prepared handle once (vec_size matched to the
    //    quickstart artifact). `EngineKind::Auto` would let the
    //    roofline model pick the engine instead.
    let cfg = PreprocessConfig { vec_size_override: Some(512), ..Default::default() };
    let ctx = SpmvContext::builder(m.clone()).engine(EngineKind::Ehyb).config(cfg.clone()).build()?;
    let plan = ctx.plan().expect("EHYB context carries a plan");
    println!(
        "EHYB: {} partitions x {} rows; ER = {:.2}% of nnz; ELL fill = {:.3}; {:.1}% smaller than u32 cols",
        plan.matrix.num_parts,
        plan.matrix.vec_size,
        100.0 * plan.matrix.er_fraction(),
        plan.matrix.ell_fill_ratio(),
        100.0 * (1.0 - plan.matrix.bytes() as f64 / plan.matrix.bytes_u32_cols() as f64),
    );
    println!(
        "preprocessing: partition {:.3}s + reorder {:.3}s",
        plan.timings.partition_secs, plan.timings.reorder_secs
    );

    // 3. SpMV three ways.
    let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
    let oracle = m.spmv_f64_oracle(&x);

    let y_cpu = ctx.spmv_alloc(&x)?;
    assert_allclose(&y_cpu, &oracle, 1e-10, 1e-10).map_err(|e| anyhow::anyhow!(e))?;
    println!("CPU EHYB engine (ctx.spmv): matches oracle");

    // Batched SpMV over ONE contiguous allocation per side: the blocked
    // SpMM kernel streams the matrix once per register block instead of
    // once per vector.
    let mut xs = BatchBuf::<f64>::zeros(n, 4);
    for t in 0..4 {
        for i in 0..n {
            xs.col_mut(t)[i] = ((i * 3 + t * 7) % 13) as f64 * 0.5 - 3.0;
        }
    }
    let mut ys = BatchBuf::<f64>::zeros(n, 4);
    {
        let mut ysv = ys.view_mut();
        ctx.spmv_batch(xs.view(), &mut ysv)?; // ys.col(b) = A * xs.col(b)
    }
    for b in 0..4 {
        assert_allclose(ys.col(b), &m.spmv_f64_oracle(xs.col(b)), 1e-10, 1e-10)
            .map_err(|e| anyhow::anyhow!(e))?;
    }
    println!("CPU EHYB ctx.spmv_batch (B=4): matches oracle");

    // Bad input lengths are typed errors, not panics.
    assert!(matches!(
        ctx.spmv_alloc(&x[..n - 1]),
        Err(ehyb::EhybError::DimensionMismatch { .. })
    ));

    match ehyb::runtime::PjrtRuntime::new("artifacts") {
        Ok(rt) => {
            let pjrt = rt.spmv_engine(&plan.matrix)?;
            let mut y_pjrt = vec![0.0; n];
            pjrt.spmv(&x, &mut y_pjrt)?;
            assert_allclose(&y_pjrt, &oracle, 1e-9, 1e-9).map_err(|e| anyhow::anyhow!(e))?;
            println!("PJRT ({}) via AOT artifact: matches oracle", rt.platform());
        }
        Err(e) => println!("PJRT skipped ({e}) — run `make artifacts`"),
    }

    // 4. Simulated V100 comparison.
    let run = runner::run_matrix("quickstart", "demo", &m, &cfg, &GpuDevice::v100())?;
    println!("\nsimulated V100:");
    for row in &run.rows {
        let speedup = run.gflops_of("ehyb").unwrap() / row.gflops;
        println!(
            "  {:>15}: {:7.2} GFLOPS{}",
            row.framework,
            row.gflops,
            if row.framework == "ehyb" {
                String::new()
            } else {
                format!("  (EHYB is {speedup:.2}x)")
            }
        );
    }
    Ok(())
}
