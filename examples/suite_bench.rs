//! Paper-evaluation driver: regenerates the Figure 3/5 series, the
//! Table 1/2 speedup statistics (over the 16-matrix corpus for speed;
//! use `ehyb bench --table 1 --scale small` for the full 94), and the
//! Figure 6 preprocessing decomposition — all on the simulated V100.
//!
//! ```text
//! EHYB_SUITE_SCALE=tiny cargo run --release --example suite_bench   # fast
//! cargo run --release --example suite_bench                         # default
//! ```

use ehyb::gpu::GpuDevice;
use ehyb::harness::{report, runner, suite, tables};
use ehyb::preprocess::PreprocessConfig;
use ehyb::sparse::csr::Csr;

fn main() -> anyhow::Result<()> {
    let scale = suite::Scale::from_env();
    let dev = GpuDevice::v100();
    let specs = suite::suite16(scale);
    println!("running {} matrices at {:?} scale on simulated {}\n", specs.len(), scale, dev.name);

    let mut runs32 = Vec::new();
    let mut runs64 = Vec::new();
    for spec in &specs {
        let m64 = spec.build();
        let m32: Csr<f32> = m64.cast();
        let cfg = PreprocessConfig::default();
        let r32 = runner::run_matrix(&spec.name, spec.category, &m32, &cfg, &dev)?;
        let r64 = runner::run_matrix(&spec.name, spec.category, &m64, &cfg, &dev)?;
        println!(
            "{:>20}: n={:>7} nnz={:>9}  f32 ehyb {:6.1} GF (vs alg2 {:4.2}x)   f64 ehyb {:6.1} GF (vs alg2 {:4.2}x)",
            spec.name,
            r64.n,
            r64.nnz,
            r32.gflops_of("ehyb").unwrap_or(0.0),
            r32.speedup_vs("cusparse-alg2").unwrap_or(0.0),
            r64.gflops_of("ehyb").unwrap_or(0.0),
            r64.speedup_vs("cusparse-alg2").unwrap_or(0.0),
        );
        runs32.push(r32);
        runs64.push(r64);
    }

    // Figure 3/5 summaries.
    println!("\nFigure 3 (single precision):");
    println!("{}", report::figure_summary(&tables::figure_series::<f32>(&runs32)));
    println!("Figure 5 (double precision):");
    println!("{}", report::figure_summary(&tables::figure_series::<f64>(&runs64)));

    // Table 1/2 over this corpus.
    println!(
        "{}",
        report::speedup_markdown(
            "Table 1 (single precision, 16-matrix corpus)",
            &tables::speedup_table::<f32>(&runs32)
        )
    );
    println!(
        "{}",
        report::speedup_markdown(
            "Table 2 (double precision, 16-matrix corpus)",
            &tables::speedup_table::<f64>(&runs64)
        )
    );

    // Figure 6.
    println!("Figure 6 — preprocessing cost in units of one (simulated) SpMV:");
    println!("{}", report::fig6_markdown(&tables::fig6_rows(&runs64)));
    Ok(())
}
