//! Row-sharded execution end to end (the CI shard gate runs exactly
//! this).
//!
//! ```text
//! cargo run --release --example sharded
//! ```
//!
//! 1. Build one context unsharded and one sharded (`ShardSpec::Auto`,
//!    cache-aware boundaries) and compare single-vector throughput.
//! 2. Verify the numerical contract: bitwise identity on a row-local
//!    engine, roundoff-equivalence on the per-shard-repartitioned EHYB
//!    engine.
//! 3. Serve a burst of requests through the sharded engine (one fused
//!    batch per shard per drain) with a shed-rate-adaptive batch limit,
//!    then print the per-shard and service metric tables.

use ehyb::harness::report;
use ehyb::preprocess::PreprocessConfig;
use ehyb::sparse::gen::unstructured_mesh;
use ehyb::spmv::SpmvEngine;
use ehyb::util::check::assert_allclose;
use ehyb::util::timer::bench_secs;
use ehyb::{EngineKind, ShardSpec, SpmvContext};
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let m = unstructured_mesh::<f64>(96, 96, 0.5, 7);
    let n = m.nrows();
    let cfg = PreprocessConfig::default();
    println!("matrix      : n={} nnz={}", n, m.nnz());

    // 1. Unsharded vs sharded throughput on the same engine kind.
    let base =
        SpmvContext::builder(m.clone()).engine(EngineKind::Ehyb).config(cfg.clone()).build()?;
    let ctx = SpmvContext::builder(m.clone())
        .engine(EngineKind::Ehyb)
        .config(cfg.clone())
        .shards(ShardSpec::Auto)
        .build()?;
    println!(
        "shards      : {} (row ranges {:?} ...)",
        ctx.shards(),
        &ctx.shard_ranges().expect("sharded build")[..2.min(ctx.shards())]
    );
    let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.17).sin()).collect();
    let mut y = vec![0.0; n];
    let secs_base = bench_secs(|| base.engine().spmv(&x, &mut y), 3, Duration::from_millis(150));
    let secs_shard = bench_secs(|| ctx.engine().spmv(&x, &mut y), 3, Duration::from_millis(150));
    println!(
        "spmv        : unsharded {:.3} GFLOPS vs sharded {:.3} GFLOPS ({:.2}x)",
        ehyb::spmv::gflops(m.nnz(), secs_base),
        ehyb::spmv::gflops(m.nnz(), secs_shard),
        secs_base / secs_shard
    );

    // 2. Numerical contract.
    let oracle = m.spmv_f64_oracle(&x);
    assert_allclose(&ctx.spmv_alloc(&x)?, &oracle, 1e-10, 1e-10).map_err(|e| anyhow::anyhow!(e))?;
    let row_local = SpmvContext::builder(m.clone()).engine(EngineKind::CsrScalar).build()?;
    let row_local_sharded = SpmvContext::builder(m.clone())
        .engine(EngineKind::CsrScalar)
        .shards(ShardSpec::Count(7))
        .build()?;
    anyhow::ensure!(
        row_local.spmv_alloc(&x)? == row_local_sharded.spmv_alloc(&x)?,
        "row-local engine must shard bit-identically"
    );
    println!("contract    : csr-scalar bitwise across shards; ehyb matches oracle");

    // 3. Sharded serving with an adaptive fused-batch limit.
    let svc = ctx.serve_adaptive(16, 64)?;
    let client = svc.client();
    let xs: Vec<Vec<f64>> = (0..48)
        .map(|t| (0..n).map(|i| ((i * 3 + t * 13) % 23) as f64 * 0.25 - 2.5).collect())
        .collect();
    let ys = client.spmv_many(xs.clone())?;
    for (xq, yq) in xs.iter().zip(&ys) {
        let want = m.spmv_f64_oracle(xq);
        assert_allclose(yq, &want, 1e-10, 1e-10).map_err(|e| anyhow::anyhow!(e))?;
    }
    println!("{}", report::service_markdown("Sharded service", &svc.metrics));
    println!(
        "{}",
        report::shard_markdown("Per-shard execution", ctx.sharded().expect("sharded build"))
    );

    println!("ok");
    Ok(())
}
