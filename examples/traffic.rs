//! Storage-traffic simulation end to end (the CI traffic gate runs
//! exactly this).
//!
//! ```text
//! cargo run --release --example traffic
//! ```
//!
//! 1. Replay every engine's prepared plan over a FEM-like mesh and
//!    print the per-engine traffic table: simulated DRAM/L2/shm bytes,
//!    L2 hit rate, x-reuse factor, hit-aware predicted time next to
//!    measured CPU GFLOPS.
//! 2. Assert the ISSUE 7 headline: EHYB's explicit cache moves no more
//!    x DRAM bytes than the CSR gather walk, and its shared-memory
//!    level actually serves traffic.
//! 3. Replay a 4-way row sharding and print the attributable halo
//!    (cross-shard x) DRAM bytes.
//! 4. Run the oracle-vs-measured validation on two matrices and print
//!    the agreement table.

use ehyb::gpu::GpuDevice;
use ehyb::harness::ablation::traffic_ablation;
use ehyb::harness::report;
use ehyb::harness::traffic_validation;
use ehyb::preprocess::{EhybPlan, PreprocessConfig};
use ehyb::shard::{ShardPlan, ShardStrategy};
use ehyb::sparse::gen::{poisson2d, unstructured_mesh};
use ehyb::traffic::{baseline_traffic, ehyb_traffic, shard_traffic};
use ehyb::EngineKind;

fn main() -> anyhow::Result<()> {
    let dev = GpuDevice::v100();
    let cfg = PreprocessConfig { vec_size_override: Some(256), ..Default::default() };
    let m = unstructured_mesh::<f64>(56, 56, 0.4, 7);

    // 1. Per-engine replay table (simulated bytes next to measured
    // GFLOPS — the same table `ehyb ablation --which traffic` emits).
    let rows = traffic_ablation(&m, &cfg, &dev)?;
    println!(
        "{}",
        report::traffic_markdown("unstructured-mesh (3.1k) — simulated storage traffic", &rows)
    );

    // 2. The paper's §3.1 claim as a byte count: the explicit cache
    // fetches each x slice once, so EHYB must not move more x DRAM
    // bytes than the CSR gather walk re-fetching through L2.
    let plan = EhybPlan::build(&m, &cfg)?;
    let e = ehyb_traffic(&plan.matrix, &dev);
    let c = baseline_traffic(EngineKind::CsrVector, &m, &dev);
    anyhow::ensure!(e.shm.read_bytes > 0, "EHYB ELL gathers must be shm-served");
    anyhow::ensure!(
        e.x.dram_bytes <= c.x.dram_bytes,
        "ehyb x DRAM {} exceeds csr-vector x DRAM {}",
        e.x.dram_bytes,
        c.x.dram_bytes
    );
    println!(
        "x DRAM      : ehyb {} B (reuse {:.2}) vs csr-vector {} B (reuse {:.2})",
        e.x.dram_bytes,
        e.x.reuse_factor(),
        c.x.dram_bytes,
        c.x.reuse_factor()
    );
    println!(
        "predicted   : ehyb {:.2} us vs csr-vector {:.2} us (hit-aware replay)",
        1e6 * e.predicted_secs,
        1e6 * c.predicted_secs
    );

    // 3. Shard replay: halo gathers are attributable bytes, not a proxy.
    let sm = poisson2d::<f64>(64, 64);
    let splan = ShardPlan::new(&sm, 4, ShardStrategy::NnzBalanced);
    let st = shard_traffic(&sm, &splan, &dev);
    anyhow::ensure!(st.shards.len() == 4);
    anyhow::ensure!(st.halo_dram_bytes > 0, "5-point stencil must cross shard boundaries");
    println!(
        "shards      : 4 x csr replay, total DRAM {} B, halo x DRAM {} B, halo nnz {:?}",
        st.total_dram_bytes(),
        st.halo_dram_bytes,
        st.halo_nnz
    );
    println!("shard bound : {:.2} us (slowest shard)", 1e6 * st.predicted_secs());

    // 4. Oracle-vs-measured validation (the `bench --validate` mode).
    let mut vrows = Vec::new();
    for (name, vm) in [
        ("poisson2d-48", poisson2d::<f64>(48, 48)),
        ("mesh-40", unstructured_mesh::<f64>(40, 40, 0.5, 3)),
    ] {
        vrows.push(traffic_validation(name, &vm, &PreprocessConfig::default())?);
    }
    println!(
        "{}",
        report::traffic_validation_markdown("Traffic oracle vs measured winner", &vrows)
    );

    println!("ok");
    Ok(())
}
