//! Format tour: one irregular matrix through every storage format the
//! SpMV-on-GPU literature uses, with the size/padding/traffic trade-offs
//! that motivate EHYB (paper §2.2, §3.4).
//!
//! ```text
//! cargo run --release --example format_tour
//! ```

use ehyb::gpu::GpuDevice;
use ehyb::perfmodel;
use ehyb::sparse::dia::Dia;
use ehyb::sparse::ell::Ell;
use ehyb::sparse::gen::{circuit, poisson3d};
use ehyb::sparse::hyb::Hyb;
use ehyb::sparse::sellp::SellP;
use ehyb::sparse::stats::MatrixStats;
use ehyb::{EngineKind, SpmvContext};

fn main() -> anyhow::Result<()> {
    for (label, m) in [
        ("poisson3d 24^3 (structured CFD)", poisson3d::<f64>(24, 24, 24)),
        ("circuit 20k (power-law rows)", circuit::<f64>(20_000, 4, 0.01, 7)),
    ] {
        println!("=== {label}: {} ===", MatrixStats::of(&m).oneline());
        let nnz = m.nnz() as f64;

        println!("  {:<10} {:>12} {:>10} {:>8}", "format", "bytes", "B/nnz", "fill");
        println!("  {:<10} {:>12} {:>10.2} {:>8}", "csr", m.bytes(), m.bytes() as f64 / nnz, "-");

        let ell = Ell::from_csr(&m);
        println!(
            "  {:<10} {:>12} {:>10.2} {:>8.2}",
            "ell",
            ell.bytes(),
            ell.bytes() as f64 / nnz,
            ell.fill_ratio()
        );

        let hyb = Hyb::from_csr_auto(&m, 2.0 / 3.0);
        println!(
            "  {:<10} {:>12} {:>10.2} {:>8}",
            "hyb",
            hyb.bytes(),
            hyb.bytes() as f64 / nnz,
            format!("{}+{}", hyb.ell.nnz(), hyb.coo.nnz())
        );

        let sellp = SellP::from_csr(&m, 32);
        println!(
            "  {:<10} {:>12} {:>10.2} {:>8.2}",
            "sellp",
            sellp.bytes(),
            sellp.bytes() as f64 / nnz,
            sellp.fill_ratio()
        );

        match Dia::from_csr(&m, 64) {
            Some(dia) => println!(
                "  {:<10} {:>12} {:>10.2} {:>8}",
                "dia",
                dia.bytes(),
                dia.bytes() as f64 / nnz,
                format!("{} diags", dia.num_diags())
            ),
            None => println!("  {:<10} {:>12}", "dia", "unsuitable (>64 diagonals)"),
        }

        let ctx = SpmvContext::builder(m.clone()).engine(EngineKind::Ehyb).build()?;
        let e = &ctx.plan().expect("EHYB context carries a plan").matrix;
        println!(
            "  {:<10} {:>12} {:>10.2} {:>8.2}  (ER {:.1}%, u16 cols save {} bytes)",
            "ehyb",
            e.bytes(),
            e.bytes() as f64 / nnz,
            e.ell_fill_ratio(),
            100.0 * e.er_fraction(),
            e.bytes_u32_cols() - e.bytes()
        );

        // Roofline boundaries (the abstract's "theory up-boundary").
        let dev = GpuDevice::v100();
        let csr_bound = perfmodel::csr_bound(&m).roofline_gflops(m.nnz(), &dev);
        let ehyb_bound = perfmodel::ehyb_bound(e).roofline_gflops(e.nnz(), &dev);
        println!(
            "  roofline: CSR-family bound {:.0} GFLOPS, EHYB bound {:.0} GFLOPS ({:+.1}%)\n",
            csr_bound,
            ehyb_bound,
            100.0 * (ehyb_bound / csr_bound - 1.0)
        );
    }
    Ok(())
}
