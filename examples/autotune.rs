//! Autotune end to end: tune → persist → reload (the CI smoke job runs
//! exactly this).
//!
//! ```text
//! cargo run --release --example autotune
//! ```
//!
//! 1. Fingerprint a mesh matrix and run the measured tuner over the
//!    EHYB plan space (slice height, partition size vs. the scratchpad
//!    budget, ELL/ER width cutoff) under a wall-clock budget.
//! 2. Persist the winning plan in a plan-cache directory (atomic JSON,
//!    keyed by fingerprint × device × dtype).
//! 3. Rebuild from a fresh builder pointed at the same cache: the plan
//!    loads with zero search and produces a byte-identical `EhybMatrix`
//!    and identical SpMV results.

use ehyb::autotune::{Fingerprint, PlanStore, TuneLevel};
use ehyb::preprocess::PreprocessConfig;
use ehyb::sparse::gen::unstructured_mesh;
use ehyb::util::check::assert_allclose;
use ehyb::util::Timer;
use ehyb::{EngineKind, SpmvContext};

fn main() -> anyhow::Result<()> {
    let dir = std::env::temp_dir().join(format!("ehyb-autotune-example-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();

    // 1. Matrix + fingerprint.
    let m = unstructured_mesh::<f64>(64, 64, 0.4, 42);
    let n = m.nrows();
    let fp = Fingerprint::of(&m);
    println!("matrix      : n={} nnz={} fingerprint={}", n, m.nnz(), fp.key());

    // 2. Tune (measured probes, budget-capped) and persist. The budget
    //    is generous so the search completes even on slow CI machines —
    //    a budget-starved search (nothing compared) is deliberately not
    //    persisted.
    let cfg = PreprocessConfig { vec_size_override: Some(256), ..Default::default() };
    let budget = TuneLevel::Measured { budget: std::time::Duration::from_secs(10) };
    let t = Timer::start();
    let ctx = SpmvContext::builder(m.clone())
        .engine(EngineKind::Ehyb)
        .config(cfg.clone())
        .tune(budget)
        .plan_cache(&dir)
        .build()?;
    let cold_secs = t.elapsed_secs();
    let tp = ctx.tuned().expect("tuner-routed build carries a TunedPlan").clone();
    println!(
        "tuned plan  : engine={} slice_height={} vec_size={:?} cutoff={:?}",
        tp.engine.name(),
        tp.slice_height,
        tp.vec_size,
        tp.ell_width_cutoff
    );
    println!(
        "score       : {:.3e}s vs default {:.3e}s ({} level)",
        tp.score_secs, tp.default_score_secs, tp.level
    );
    anyhow::ensure!(
        tp.score_secs <= tp.default_score_secs,
        "selection guarantee violated: tuned plan scored worse than default"
    );

    let store = PlanStore::new(&dir);
    let cache_file = store.path_for(&tp.fingerprint, &tp.device, &tp.dtype, &tp.scope);
    anyhow::ensure!(cache_file.exists(), "plan was not persisted at {}", cache_file.display());
    println!(
        "persisted   : {} ({} bytes)",
        cache_file.display(),
        std::fs::metadata(&cache_file)?.len()
    );

    // 3. Reload: a fresh builder on the same cache dir must adopt the
    //    stored plan without searching, and agree exactly.
    let t = Timer::start();
    let ctx2 = SpmvContext::builder(m.clone())
        .engine(EngineKind::Ehyb)
        .config(cfg)
        .tune(budget)
        .plan_cache(&dir)
        .build()?;
    let warm_secs = t.elapsed_secs();
    anyhow::ensure!(ctx2.tuned() == Some(&tp), "reloaded plan differs from the persisted one");
    anyhow::ensure!(
        ctx.plan().unwrap().matrix == ctx2.plan().unwrap().matrix,
        "cache round-trip did not rebuild a byte-identical EhybMatrix"
    );
    println!(
        "reload      : cache hit verified ({cold_secs:.3}s cold build -> {warm_secs:.3}s warm)"
    );

    // Correctness of the tuned pipeline.
    let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.29).cos()).collect();
    let oracle = m.spmv_f64_oracle(&x);
    assert_allclose(&ctx.spmv_alloc(&x)?, &oracle, 1e-10, 1e-10).map_err(|e| anyhow::anyhow!(e))?;
    let y2 = ctx2.spmv_alloc(&x)?;
    assert_allclose(&y2, &oracle, 1e-10, 1e-10).map_err(|e| anyhow::anyhow!(e))?;
    println!("spmv        : tuned + reloaded contexts match the oracle");

    std::fs::remove_dir_all(&dir).ok();
    println!("ok");
    Ok(())
}
