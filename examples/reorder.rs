//! Global matrix reordering end to end (the CI reorder gate runs
//! exactly this).
//!
//! ```text
//! cargo run --release --example reorder
//! ```
//!
//! 1. Run the reorder ablation on a scrambled banded matrix and an
//!    unstructured mesh: per-spec bandwidth / profile / windowed
//!    footprint / cache-aware `cut_nnz` / simulated GFLOPS markdown.
//! 2. Assert the ISSUE 5 acceptance criterion: `Rcm` and
//!    `PartitionRank` each reduce bandwidth AND the cache-aware
//!    cross-shard cut versus `None`.
//! 3. Build reordered contexts through the facade (reorder × shards),
//!    verify results against the oracle in original index space, and
//!    compare CPU wall-clock throughput reorder-off vs reorder-on.

use ehyb::gpu::GpuDevice;
use ehyb::harness::ablation::reorder_ablation;
use ehyb::harness::report;
use ehyb::preprocess::PreprocessConfig;
use ehyb::sparse::csr::Csr;
use ehyb::sparse::gen::{banded, unstructured_mesh};
use ehyb::spmv::SpmvEngine;
use ehyb::util::check::assert_allclose;
use ehyb::util::timer::bench_secs;
use ehyb::util::Xoshiro256;
use ehyb::{EngineKind, ReorderSpec, ShardSpec, SpmvContext};
use std::time::Duration;

/// A banded matrix hidden behind a random relabeling — locality exists,
/// the natural order lost it, a good ordering must find it again.
fn scrambled_banded(n: usize, bw: usize, seed: u64) -> Csr<f64> {
    let m = banded::<f64>(n, bw, 0.7, seed);
    let mut shuffle: Vec<u32> = (0..n as u32).collect();
    Xoshiro256::new(seed ^ 0xD1CE).shuffle(&mut shuffle);
    m.permute_symmetric_stable(&shuffle)
}

fn main() -> anyhow::Result<()> {
    let dev = GpuDevice::v100();
    let cfg = PreprocessConfig { vec_size_override: Some(256), ..Default::default() };
    let shards_k = 8;

    // 1 + 2: ablation tables with the acceptance assertions.
    let cases: Vec<(&str, Csr<f64>)> = vec![
        ("scrambled-banded (3k)", scrambled_banded(3000, 8, 11)),
        ("unstructured-mesh (2.3k, FEM-like)", unstructured_mesh::<f64>(48, 48, 0.4, 5)),
    ];
    for (name, m) in &cases {
        let rows = reorder_ablation(m, &cfg, &dev, shards_k)?;
        println!(
            "{}",
            report::reorder_markdown(
                &format!("{name} — reorder ablation (cut at K={shards_k} cache-aware shards)"),
                &rows
            )
        );
        let row = |tag: &str| {
            rows.iter()
                .find(|r| r.spec == tag || r.spec.starts_with(tag))
                .unwrap_or_else(|| panic!("missing ablation row {tag}"))
        };
        let none = row("none");
        for tag in ["rcm", "partrank"] {
            let r = row(tag);
            anyhow::ensure!(
                r.bandwidth < none.bandwidth,
                "{name}: {tag} bandwidth {} must beat natural {}",
                r.bandwidth,
                none.bandwidth
            );
            anyhow::ensure!(
                r.cut_nnz < none.cut_nnz,
                "{name}: {tag} cut_nnz {} must beat natural {}",
                r.cut_nnz,
                none.cut_nnz
            );
        }
        println!(
            "acceptance  : rcm + partrank reduce bandwidth and cache-aware cut on {name}\n"
        );
    }

    // 3. Facade: reorder × shards, user-facing vectors stay in original
    // index space.
    let (_, m) = &cases[0];
    let n = m.nrows();
    let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.13).sin()).collect();
    let oracle = m.spmv_f64_oracle(&x);
    let mut y = vec![0.0f64; n];
    let mut gflops = Vec::new();
    for (tag, spec) in [("off", ReorderSpec::None), ("rcm", ReorderSpec::Rcm)] {
        let ctx = SpmvContext::builder(m.clone())
            .engine(EngineKind::Ehyb)
            .config(cfg.clone())
            .reorder(spec)
            .shards(ShardSpec::Count(4))
            .build()?;
        assert_allclose(&ctx.spmv_alloc(&x)?, &oracle, 1e-9, 1e-9)
            .map_err(|e| anyhow::anyhow!("reorder={tag}: {e}"))?;
        let e = ctx.engine();
        let secs = bench_secs(|| e.spmv(&x, &mut y), 3, Duration::from_millis(100));
        gflops.push((tag, ehyb::spmv::gflops(m.nnz(), secs)));
        if let Some((before, after)) = ctx.reorder_cut_nnz() {
            anyhow::ensure!(
                after < before,
                "reordered shard cut {after} must beat natural {before}"
            );
            println!("shard cut   : {before} -> {after} cross-shard entries (reorder={tag})");
        }
    }
    for (tag, gf) in &gflops {
        println!("spmv        : reorder={tag:<4} {gf:.3} GFLOPS (4 row shards, cpu wallclock)");
    }

    // Row-local bitwise contract through the full facade stack.
    let plain = SpmvContext::builder(m.clone()).engine(EngineKind::CsrScalar).build()?;
    let reordered = SpmvContext::builder(m.clone())
        .engine(EngineKind::CsrScalar)
        .reorder(ReorderSpec::Rcm)
        .build()?;
    anyhow::ensure!(
        plain.spmv_alloc(&x)? == reordered.spmv_alloc(&x)?,
        "row-local engine must be bitwise identical under reordering"
    );
    println!("contract    : csr-scalar bitwise with reordering on; ehyb matches oracle");

    println!("ok");
    Ok(())
}
