//! End-to-end FEM solve — the repo's E2E validation driver
//! (EXPERIMENTS.md §E2E): a 3-D Poisson problem with 64,000 unknowns is
//! solved with Jacobi-preconditioned CG whose SpMV runs through the
//! full three-layer stack (Pallas kernel → JAX graph → AOT HLO → Rust
//! PJRT), logging the residual curve, then re-solved with the
//! [`SpmvContext`] solver handle and the SpMV service spawned from the
//! same context. Finishes with the paper §6 amortization accounting.
//!
//! ```text
//! make artifacts && cargo run --release --example fem_solver
//! ```

use ehyb::coordinator::{cg, Jacobi, SolverConfig};
use ehyb::preprocess::PreprocessConfig;
use ehyb::sparse::gen::poisson3d;
use ehyb::util::Timer;
use ehyb::{EngineKind, SpmvContext};

fn main() -> anyhow::Result<()> {
    // Problem: -Δu = f on a 40^3 grid (64,000 unknowns — the `solver`
    // artifact bucket), f = alternating point sources.
    let (nx, ny, nz) = (40, 40, 40);
    let a = poisson3d::<f64>(nx, ny, nz);
    let n = a.nrows();
    let b: Vec<f64> = (0..n).map(|i| if i % 97 == 0 { 1.0 } else { 0.0 }).collect();
    println!("system: 3D Poisson {nx}x{ny}x{nz} -> n={n}, nnz={}", a.nnz());

    // Preprocess once behind the facade (vec_size matches the solver
    // bucket's R); everything below — PJRT, CPU solve, service — runs
    // off this one prepared context.
    let cfg = PreprocessConfig { vec_size_override: Some(512), ..Default::default() };
    let t = Timer::start();
    let ctx = SpmvContext::builder(a.clone()).engine(EngineKind::Ehyb).config(cfg).build()?;
    let plan = ctx.plan().expect("EHYB context carries a plan");
    println!(
        "preprocess: {:.3}s (partition {:.3}s, reorder {:.3}s); {} partitions, ER {:.2}%",
        t.elapsed_secs(),
        plan.timings.partition_secs,
        plan.timings.reorder_secs,
        plan.matrix.num_parts,
        100.0 * plan.matrix.er_fraction()
    );

    let pre = Jacobi::new(ctx.matrix());
    let scfg = SolverConfig { max_iters: 600, rtol: 1e-8, ..Default::default() };

    // --- Solve 1: full three-layer stack over PJRT. ---
    let pjrt_report = match ehyb::runtime::PjrtRuntime::new("artifacts") {
        Ok(rt) => {
            let engine = rt.spmv_engine(&plan.matrix)?;
            println!("\n[PJRT] solving via AOT artifact on {} ...", rt.platform());
            let x0 = vec![0.0; n];
            let (x, rep) =
                cg(|v: &[f64], y: &mut [f64]| engine.spmv(v, y).unwrap(), &b, &x0, &pre, &scfg);
            print_history("pjrt-cg", &rep.history);
            verify(&a, &x, &b);
            println!(
                "[PJRT] {} iters in {:.2}s ({:.2} ms/SpMV), converged={}",
                rep.iters,
                rep.wall_secs,
                1e3 * rep.wall_secs / rep.spmv_count as f64,
                rep.converged()
            );
            Some(rep)
        }
        Err(e) => {
            println!("[PJRT] skipped: {e} (run `make artifacts`)");
            None
        }
    };

    // --- Solve 2: the context's solver handle over the prepared CPU
    //     engine (dimension-checked, typed errors). ---
    println!("\n[CPU ] solving via ctx.solver().cg ...");
    let (x, cpu_rep) = ctx.solver().cg(&b, None, &pre, &scfg)?;
    verify(&a, &x, &b);
    println!(
        "[CPU ] {} iters in {:.2}s ({:.3} ms/SpMV), converged={}",
        cpu_rep.iters,
        cpu_rep.wall_secs,
        1e3 * cpu_rep.wall_secs / cpu_rep.spmv_count as f64,
        cpu_rep.converged()
    );

    // --- Solve 3: through the batched SpMV service (leader/worker),
    //     spawned straight off the context. ---
    let svc = ctx.serve(16)?;
    let client = svc.client();
    println!("\n[SVC ] solving via SpMV service ...");
    let (x, svc_rep) = cg(
        |v: &[f64], y: &mut [f64]| {
            let out = client.spmv(v.to_vec()).unwrap();
            y.copy_from_slice(&out);
        },
        &b,
        &vec![0.0; n],
        &pre,
        &scfg,
    );
    verify(&a, &x, &b);
    println!(
        "[SVC ] {} iters in {:.2}s; service mean latency {:.3} ms, p99 {:.3} ms over {} requests",
        svc_rep.iters,
        svc_rep.wall_secs,
        1e3 * svc.metrics.spmv_latency.mean_secs(),
        1e3 * svc.metrics.spmv_latency.quantile_secs(0.99),
        svc.metrics.spmv_latency.count()
    );
    {
        use std::sync::atomic::Ordering;
        println!(
            "[SVC ] {} fused batches, mean width {:.2}, ~{:.1} MiB streamed",
            svc.metrics.batches.load(Ordering::Relaxed),
            svc.metrics.batch_width.mean(),
            svc.metrics.bytes_moved.load(Ordering::Relaxed) as f64 / (1u64 << 20) as f64
        );
    }

    // --- Multi-RHS: several load cases fused per iteration. ---
    let bs: Vec<Vec<f64>> = (0..3)
        .map(|t| (0..n).map(|i| if i % (89 + t) == 0 { 1.0 } else { 0.0 }).collect())
        .collect();
    let many = ctx.solver().cg_many(&bs, &pre, &scfg)?;
    for (i, (xm, rep)) in many.iter().enumerate() {
        verify(&a, xm, &bs[i]);
        println!("[MANY] rhs {i}: {} iters, converged={}", rep.iters, rep.converged());
    }

    // --- §6 amortization accounting. ---
    let rep = pjrt_report.as_ref().unwrap_or(&cpu_rep);
    let per_spmv = rep.wall_secs / rep.spmv_count.max(1) as f64;
    let prep_x = plan.timings.total_secs() / per_spmv;
    println!(
        "\n§6 amortization: preprocessing = {:.0}x one SpMV; over this solve's {} SpMVs the \
         overhead is {:.1}%; a transient simulation re-solving {}00 timesteps amortizes it to {:.3}%",
        prep_x,
        rep.spmv_count,
        100.0 * plan.timings.total_secs()
            / (rep.wall_secs + plan.timings.total_secs()),
        5,
        100.0 * plan.timings.total_secs()
            / (500.0 * rep.wall_secs + plan.timings.total_secs()),
    );
    Ok(())
}

fn print_history(tag: &str, history: &[f64]) {
    print!("{tag} residual curve: ");
    for (i, r) in history.iter().enumerate() {
        if i % 25 == 0 {
            print!("it{i}:{r:.2e} ");
        }
    }
    if let Some(last) = history.last() {
        print!("final:{last:.2e}");
    }
    println!();
}

fn verify(a: &ehyb::sparse::csr::Csr<f64>, x: &[f64], b: &[f64]) {
    let mut ax = vec![0.0; b.len()];
    a.spmv(x, &mut ax);
    let num: f64 = ax.iter().zip(b).map(|(p, q)| (p - q) * (p - q)).sum::<f64>().sqrt();
    let den: f64 = b.iter().map(|v| v * v).sum::<f64>().sqrt();
    assert!(num / den < 1e-6, "solution check failed: {}", num / den);
    println!("       solution verified: |Ax-b|/|b| = {:.2e}", num / den);
}
