"""L2 — the EHYB SpMV compute graph (and a fused CG step), written in
JAX on top of the L1 Pallas kernel, lowered once by ``aot.py`` to HLO
text that the Rust runtime loads.

Everything operates in the **new (reordered) index space**: the Rust
coordinator permutes x once per solve (not per SpMV) and un-permutes y
at the end, exactly as the CUDA implementation keeps its vectors
pre-permuted on the device.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.ehyb import ell_spmv


def ehyb_spmv(xp, ell_cols, ell_vals, er_cols, er_vals, er_yidx):
    """Full EHYB SpMV: sliced-ELL (explicitly cached) + ER scatter-add.

    Args:
      xp:       (P*R,) input vector, new index space, padded.
      ell_cols: (P, W, R) int32 partition-local columns.
      ell_vals: (P, W, R) values.
      er_cols:  (E, WE) int32 global (new-order) columns.
      er_vals:  (E, WE) values (padding rows all-zero).
      er_yidx:  (E,) int32 output row of each ER row (padding -> 0 with
                zero values, so the scatter-add is inert).

    Returns:
      (P*R,) y in the new index space.
    """
    y = ell_spmv(xp, ell_cols, ell_vals)
    # ER part: uncached gathers over the full vector + scatter-add —
    # the paper processes these rows without the shared-memory cache.
    contrib = jnp.sum(er_vals * xp[er_cols], axis=1)
    return y.at[er_yidx].add(contrib)


def cg_step(xk, rk, pk, rz, ell_cols, ell_vals, er_cols, er_vals, er_yidx, diag_inv):
    """One Jacobi-preconditioned CG iteration, fused around the SpMV —
    the L2 graph the solver example runs end-to-end (§6's amortization
    argument: thousands of iterations share one preprocessing).

    State: xk (iterate), rk (residual), pk (search direction),
    rz = <r, z> from the previous step; diag_inv = 1/diag(A) (new order,
    padding slots 0).

    Returns (xk1, rk1, pk1, rz1, alpha_den) — alpha_den lets the host
    monitor breakdown.
    """
    ap = ehyb_spmv(pk, ell_cols, ell_vals, er_cols, er_vals, er_yidx)
    den = jnp.dot(pk, ap)
    alpha = rz / jnp.where(den == 0, 1.0, den)
    xk1 = xk + alpha * pk
    rk1 = rk - alpha * ap
    zk1 = diag_inv * rk1
    rz1 = jnp.dot(rk1, zk1)
    beta = rz1 / jnp.where(rz == 0, 1.0, rz)
    pk1 = zk1 + beta * pk
    return xk1, rk1, pk1, rz1, den


# ---------------------------------------------------------------------------
# Lowering helpers (the AOT bridge; see /opt/xla-example/gen_hlo.py).
# HLO *text* is the interchange format: jax >= 0.5 emits protos with
# 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
# parser reassigns ids and round-trips cleanly.
# ---------------------------------------------------------------------------


def spmv_arg_specs(dtype, p, w, r, e, we):
    """ShapeDtypeStructs for ``ehyb_spmv`` at a given bucket shape."""
    f = jnp.dtype(dtype)
    i = jnp.dtype(jnp.int32)
    return (
        jax.ShapeDtypeStruct((p * r,), f),
        jax.ShapeDtypeStruct((p, w, r), i),
        jax.ShapeDtypeStruct((p, w, r), f),
        jax.ShapeDtypeStruct((e, we), i),
        jax.ShapeDtypeStruct((e, we), f),
        jax.ShapeDtypeStruct((e,), i),
    )


def cg_arg_specs(dtype, p, w, r, e, we):
    f = jnp.dtype(dtype)
    n = p * r
    vec = jax.ShapeDtypeStruct((n,), f)
    scal = jax.ShapeDtypeStruct((), f)
    # cg_step takes the matrix arguments without the xp vector.
    matrix = spmv_arg_specs(dtype, p, w, r, e, we)[1:]
    return (vec, vec, vec, scal) + matrix + (vec,)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_spmv(dtype, p, w, r, e, we) -> str:
    lowered = jax.jit(ehyb_spmv).lower(*spmv_arg_specs(dtype, p, w, r, e, we))
    return to_hlo_text(lowered)


def lower_cg_step(dtype, p, w, r, e, we) -> str:
    lowered = jax.jit(cg_step).lower(*cg_arg_specs(dtype, p, w, r, e, we))
    return to_hlo_text(lowered)
