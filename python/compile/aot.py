"""AOT entry point: lower the L2 graphs at every shape bucket and write
HLO-text artifacts + a manifest the Rust runtime indexes.

Run once at build time (``make artifacts``); Python is never on the
request path. Usage::

    cd python && python -m compile.aot --out ../artifacts

Bucket sizing: XLA executables are shape-static, so the runtime pads a
matrix up to the smallest bucket that fits (zero padding is numerically
inert — padding slots are col=0/val=0 and padded x entries are 0). The
ladder below covers the tests, the quickstart, and the FEM-solver
example; the 94-matrix perf sweeps run on the GPU simulator, not PJRT
(DESIGN.md §8).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax

jax.config.update("jax_enable_x64", True)

from . import model  # noqa: E402


# (name, P, W, R, E, WE) — n = P*R padded rows. E is generous: for 3D
# stencils partitioned into ~512-row blocks, most rows are partition-
# boundary rows (the block's surface), so ER row counts approach n.
BUCKETS = [
    ("tiny", 4, 8, 64, 256, 4),
    ("small", 16, 16, 128, 2048, 8),
    ("quickstart", 32, 16, 512, 16384, 8),
    ("solver", 128, 8, 512, 57344, 8),
]

DTYPES = ["f32", "f64"]
_DT = {"f32": "float32", "f64": "float64"}


def artifact_name(kind: str, dtype: str, name: str) -> str:
    return f"{kind}_{dtype}_{name}.hlo.txt"


def build_all(out_dir: str, kinds=("spmv", "cg"), buckets=BUCKETS, dtypes=DTYPES) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"buckets": []}
    for name, p, w, r, e, we in buckets:
        for dt in dtypes:
            for kind in kinds:
                lower = model.lower_spmv if kind == "spmv" else model.lower_cg_step
                text = lower(_DT[dt], p, w, r, e, we)
                fname = artifact_name(kind, dt, name)
                path = os.path.join(out_dir, fname)
                with open(path, "w") as f:
                    f.write(text)
                manifest["buckets"].append(
                    {
                        "kind": kind,
                        "dtype": dt,
                        "name": name,
                        "p": p,
                        "w": w,
                        "r": r,
                        "e": e,
                        "we": we,
                        "n": p * r,
                        "file": fname,
                        "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
                    }
                )
                print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {out_dir}/manifest.json ({len(manifest['buckets'])} artifacts)")
    return manifest


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output directory")
    ap.add_argument(
        "--kinds",
        default="spmv,cg",
        help="comma-separated artifact kinds to build (spmv,cg)",
    )
    args = ap.parse_args(argv)
    build_all(args.out, kinds=tuple(args.kinds.split(",")))
    return 0


if __name__ == "__main__":
    sys.exit(main())
