"""L1 — the EHYB sliced-ELL Pallas kernel with an explicitly cached
input-vector slice.

Paper Algorithm 3 on a GPU: one CUDA block per partition copies its
x-slice into shared memory, then warps stream the partition's sliced-ELL
entries and gather x from the cache.

TPU rethink (DESIGN.md §Hardware-Adaptation): the explicit cache is a
VMEM block. ``grid = (num_parts,)`` and the x-partition BlockSpec
``lambda p: (p, 0)`` make Pallas stage exactly one partition's x-slice
into VMEM per grid step — the HBM→VMEM copy *is* Algorithm 3 line 4.
The (W, R) value/column blocks stream through VMEM the way the ELL
slices stream through the SM; the gather ``x[cols]`` vectorizes across
the 128-lane axis (R is a multiple of 128 in deployment shapes; the
kernel itself only needs R % 8 == 0).

Layout notes:

* ``cols``/``vals`` are (P, W, R): partition-major, then ELL column
  (width) index, then row-within-partition — the column-major-within-
  partition order the paper uses for coalescing; on TPU it puts the row
  axis last, i.e. across lanes.
* Column indices are **partition-local** (< R = VecSize < 2^16, paper
  §3.4). Storage in the Rust coordinator is u16; the PJRT boundary
  widens them to i32 because XLA literals have no i16 entry point in
  the runtime crate. On a real TPU the artifact would keep i16 in HBM
  and widen in-register, like the CUDA kernel does.
* Padding slots are ``col = 0, val = 0``: gather-safe and numerically
  inert.
* ``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
  custom-calls; correctness is validated on this path and real-TPU
  behaviour is estimated analytically (DESIGN.md §9).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ell_kernel(x_ref, col_ref, val_ref, o_ref):
    """One grid step = one partition (paper: one CUDA block).

    x_ref   : (1, R)  — the partition's x-slice, staged in VMEM.
    col_ref : (1, W, R) int32 — partition-local column indices.
    val_ref : (1, W, R) — matrix values (padding rows are 0).
    o_ref   : (1, R) — this partition's slice of y.
    """
    x = x_ref[0, :]  # the explicitly cached vector slice
    cols = col_ref[0]  # (W, R)
    vals = val_ref[0]  # (W, R)
    # Gather from the cached slice only — never from the full vector.
    gathered = x[cols]  # (W, R)
    o_ref[0, :] = jnp.sum(vals * gathered, axis=0)


@functools.partial(jax.jit, static_argnames=())
def ell_spmv(xp, cols, vals):
    """Sliced-ELL part of the EHYB SpMV.

    Args:
      xp:   (P*R,) input vector in the reordered (new) index space.
      cols: (P, W, R) int32 partition-local columns.
      vals: (P, W, R) values.

    Returns:
      (P*R,) the ELL part's contribution to y (new index space).
    """
    p, w, r = cols.shape
    x_parts = xp.reshape(p, r)
    out = pl.pallas_call(
        _ell_kernel,
        grid=(p,),
        in_specs=[
            pl.BlockSpec((1, r), lambda i: (i, 0)),  # x-slice: the cache
            pl.BlockSpec((1, w, r), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, w, r), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((p, r), vals.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x_parts, cols, vals)
    return out.reshape(p * r)


def vmem_bytes(p: int, w: int, r: int, dtype) -> int:
    """Estimated VMEM working set per grid step: the cached x-slice plus
    one (W, R) value block, one (W, R) int32 column block, and the output
    slice. Used by DESIGN.md §9's footprint budget (≤ 16 MiB/core)."""
    tau = jnp.dtype(dtype).itemsize
    return r * tau + w * r * tau + w * r * 4 + r * tau
