"""Pure-jnp oracle for the EHYB kernels — no Pallas, no tricks.

The pytest suite (and hypothesis sweeps) compare every kernel and the
full L2 model against these references, which are themselves validated
against a dense matrix reconstruction in ``tests/test_ref.py``.
"""

from __future__ import annotations

import jax.numpy as jnp


def ell_spmv_ref(xp, cols, vals):
    """Reference for the sliced-ELL part: identical math, no pallas_call.

    xp: (P*R,), cols/vals: (P, W, R) -> (P*R,)
    """
    p, w, r = cols.shape
    x_parts = xp.reshape(p, r)
    # Per-partition gather from the partition's own slice.
    gathered = jnp.take_along_axis(
        x_parts[:, None, :].repeat(w, axis=1), cols, axis=2
    )
    return jnp.sum(vals * gathered, axis=1).reshape(p * r)


def er_spmv_ref(xp, er_cols, er_vals):
    """ER (extra rows) part: uncached gathers over the full vector.

    er_cols/er_vals: (E, WE) with global (new-order) columns.
    Returns (E,) per-ER-row contributions.
    """
    return jnp.sum(er_vals * xp[er_cols], axis=1)


def ehyb_spmv_ref(xp, ell_cols, ell_vals, er_cols, er_vals, er_yidx):
    """Full EHYB SpMV in the new index space (see model.ehyb_spmv)."""
    y = ell_spmv_ref(xp, ell_cols, ell_vals)
    contrib = er_spmv_ref(xp, er_cols, er_vals)
    return y.at[er_yidx].add(contrib)


def dense_from_ehyb(n, ell_cols, ell_vals, er_cols, er_vals, er_yidx):
    """Reconstruct the dense operator A (new index space) from EHYB
    arrays — the ground truth the references are tested against."""
    p, w, r = ell_cols.shape
    a = jnp.zeros((n, n), dtype=ell_vals.dtype)
    for pi in range(p):
        for wi in range(w):
            for ri in range(r):
                row = pi * r + ri
                col = pi * r + int(ell_cols[pi, wi, ri])
                if row < n and col < n:
                    a = a.at[row, col].add(ell_vals[pi, wi, ri])
    e, we = er_cols.shape
    for ei in range(e):
        row = int(er_yidx[ei])
        for wi in range(we):
            col = int(er_cols[ei, wi])
            if row < n and col < n:
                a = a.at[row, col].add(er_vals[ei, wi])
    return a
