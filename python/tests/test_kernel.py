"""L1 kernel correctness: the Pallas sliced-ELL kernel vs the pure-jnp
oracle, swept over shapes and dtypes with hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ehyb import ell_spmv, vmem_bytes
from compile.kernels.ref import ell_spmv_ref


def make_ell(rng, p, w, r, dtype, pad_fraction=0.3):
    """Random sliced-ELL arrays with realistic padding (col=0/val=0)."""
    cols = rng.integers(0, r, size=(p, w, r)).astype(np.int32)
    vals = rng.standard_normal((p, w, r)).astype(dtype)
    pad = rng.random((p, w, r)) < pad_fraction
    cols[pad] = 0
    vals[pad] = 0
    xp = rng.standard_normal((p * r,)).astype(dtype)
    return xp, jnp.asarray(cols), jnp.asarray(vals)


def tol(dtype):
    return dict(rtol=1e-4, atol=1e-4) if dtype == np.float32 else dict(rtol=1e-10, atol=1e-12)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
@pytest.mark.parametrize("p,w,r", [(1, 1, 8), (2, 4, 32), (4, 8, 64), (3, 5, 40)])
def test_pallas_matches_ref(dtype, p, w, r):
    rng = np.random.default_rng(42 + p * 100 + w * 10 + r)
    xp, cols, vals = make_ell(rng, p, w, r, dtype)
    got = np.asarray(ell_spmv(jnp.asarray(xp), cols, vals))
    want = np.asarray(ell_spmv_ref(jnp.asarray(xp), cols, vals))
    np.testing.assert_allclose(got, want, **tol(dtype))


@settings(max_examples=40, deadline=None)
@given(
    p=st.integers(1, 5),
    w=st.integers(1, 9),
    r8=st.integers(1, 8),
    f64=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_pallas_matches_ref_hypothesis(p, w, r8, f64, seed):
    r = 8 * r8
    dtype = np.float64 if f64 else np.float32
    rng = np.random.default_rng(seed)
    xp, cols, vals = make_ell(rng, p, w, r, dtype)
    got = np.asarray(ell_spmv(jnp.asarray(xp), cols, vals))
    want = np.asarray(ell_spmv_ref(jnp.asarray(xp), cols, vals))
    np.testing.assert_allclose(got, want, **tol(dtype))


def test_gather_stays_in_partition():
    """A column index never reads outside its partition's slice: putting
    poison in other partitions must not change a partition's output."""
    rng = np.random.default_rng(7)
    p, w, r = 3, 4, 16
    xp, cols, vals = make_ell(rng, p, w, r, np.float64, pad_fraction=0.0)
    base = np.asarray(ell_spmv(jnp.asarray(xp), cols, vals)).reshape(p, r)
    poisoned = xp.copy().reshape(p, r)
    poisoned[1] = 1e30  # poison partition 1 only
    out = np.asarray(ell_spmv(jnp.asarray(poisoned.reshape(-1)), cols, vals)).reshape(p, r)
    np.testing.assert_allclose(out[0], base[0])
    np.testing.assert_allclose(out[2], base[2])


def test_all_padding_gives_zero():
    p, w, r = 2, 3, 8
    cols = jnp.zeros((p, w, r), jnp.int32)
    vals = jnp.zeros((p, w, r), jnp.float32)
    xp = jnp.arange(p * r, dtype=jnp.float32)
    out = np.asarray(ell_spmv(xp, cols, vals))
    np.testing.assert_array_equal(out, np.zeros(p * r, np.float32))


def test_identity_matrix():
    """cols[i]=i with val 1 in the first width slot reproduces x."""
    p, w, r = 2, 2, 16
    cols = np.zeros((p, w, r), np.int32)
    vals = np.zeros((p, w, r), np.float64)
    cols[:, 0, :] = np.arange(r)
    vals[:, 0, :] = 1.0
    xp = np.random.default_rng(0).standard_normal(p * r)
    out = np.asarray(ell_spmv(jnp.asarray(xp), jnp.asarray(cols), jnp.asarray(vals)))
    np.testing.assert_allclose(out, xp)


def test_vmem_budget_for_deployment_shapes():
    """DESIGN.md §9: the solver bucket's working set fits well under a
    16 MiB/core VMEM budget."""
    assert vmem_bytes(128, 8, 512, jnp.float64) < 16 * 2**20
    assert vmem_bytes(32, 16, 512, jnp.float32) < 16 * 2**20


def test_kernel_is_linear_in_x():
    """SpMV is linear: A(a·x + b·z) = a·Ax + b·Az — exercised through the
    jitted kernel (catches indexing bugs tolerance tests can miss)."""
    rng = np.random.default_rng(3)
    p, w, r = 2, 3, 16
    xp, cols, vals = make_ell(rng, p, w, r, np.float64)
    zp = rng.standard_normal(p * r)
    a, b = 2.5, -1.25
    lhs = np.asarray(ell_spmv(jnp.asarray(a * xp + b * zp), cols, vals))
    rhs = a * np.asarray(ell_spmv(jnp.asarray(xp), cols, vals)) + b * np.asarray(
        ell_spmv(jnp.asarray(zp), cols, vals)
    )
    np.testing.assert_allclose(lhs, rhs, rtol=1e-10, atol=1e-12)


def test_kernel_jit_matches_eager():
    rng = np.random.default_rng(4)
    p, w, r = 3, 4, 24
    xp, cols, vals = make_ell(rng, p, w, r, np.float32)
    jitted = jax.jit(ell_spmv)
    np.testing.assert_allclose(
        np.asarray(jitted(jnp.asarray(xp), cols, vals)),
        np.asarray(ell_spmv(jnp.asarray(xp), cols, vals)),
        rtol=1e-6,
    )
