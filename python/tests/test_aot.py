"""AOT lowering: HLO text is produced, parses as a module, and the
manifest indexes every bucket."""

import json
import os

import numpy as np
import jax.numpy as jnp

from compile import aot, model


def test_lower_spmv_produces_hlo_text():
    text = model.lower_spmv("float32", 2, 4, 32, 16, 4)
    assert "HloModule" in text
    # The ELL gather and the ER scatter-add must both have survived
    # lowering (gather/scatter ops present).
    assert "gather" in text
    assert "scatter" in text


def test_lower_cg_produces_hlo_text():
    text = model.lower_cg_step("float64", 2, 4, 32, 16, 4)
    assert "HloModule" in text
    assert "f64" in text


def test_build_all_manifest(tmp_path):
    out = str(tmp_path / "artifacts")
    manifest = aot.build_all(out, kinds=("spmv",), buckets=[("tiny", 2, 4, 32, 16, 4)], dtypes=["f32"])
    assert len(manifest["buckets"]) == 1
    entry = manifest["buckets"][0]
    path = os.path.join(out, entry["file"])
    assert os.path.exists(path)
    with open(os.path.join(out, "manifest.json")) as f:
        m2 = json.load(f)
    assert m2 == manifest
    assert entry["n"] == entry["p"] * entry["r"]


def test_lowered_spmv_executes_like_eager():
    """jit-compiled (the artifact's compute graph) vs eager results."""
    import jax

    rng = np.random.default_rng(3)
    p, w, r, e, we = 2, 3, 16, 8, 2
    n = p * r
    cols = jnp.asarray(rng.integers(0, r, (p, w, r)).astype(np.int32))
    vals = jnp.asarray(rng.standard_normal((p, w, r)))
    er_cols = jnp.asarray(rng.integers(0, n, (e, we)).astype(np.int32))
    er_vals = jnp.asarray(rng.standard_normal((e, we)))
    er_yidx = jnp.asarray(rng.integers(0, n, (e,)).astype(np.int32))
    xp = jnp.asarray(rng.standard_normal(n))
    jitted = jax.jit(model.ehyb_spmv)
    np.testing.assert_allclose(
        np.asarray(jitted(xp, cols, vals, er_cols, er_vals, er_yidx)),
        np.asarray(model.ehyb_spmv(xp, cols, vals, er_cols, er_vals, er_yidx)),
        rtol=1e-10,
    )
