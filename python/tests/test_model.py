"""L2 model correctness: full EHYB SpMV (ELL + ER scatter) against the
oracle and a dense reconstruction; CG-step convergence."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def make_instance(rng, p, w, r, e, we, dtype):
    n = p * r
    cols = rng.integers(0, r, size=(p, w, r)).astype(np.int32)
    vals = rng.standard_normal((p, w, r)).astype(dtype)
    pad = rng.random((p, w, r)) < 0.4
    cols[pad] = 0
    vals[pad] = 0
    er_cols = rng.integers(0, n, size=(e, we)).astype(np.int32)
    er_vals = rng.standard_normal((e, we)).astype(dtype)
    er_pad = rng.random((e, we)) < 0.5
    er_cols[er_pad] = 0
    er_vals[er_pad] = 0
    er_yidx = rng.integers(0, n, size=(e,)).astype(np.int32)
    xp = rng.standard_normal((n,)).astype(dtype)
    return tuple(jnp.asarray(a) for a in (xp, cols, vals, er_cols, er_vals, er_yidx))


def tol(dtype):
    return dict(rtol=1e-4, atol=1e-4) if dtype == np.float32 else dict(rtol=1e-10, atol=1e-12)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_full_spmv_matches_ref(dtype):
    rng = np.random.default_rng(11)
    args = make_instance(rng, 3, 4, 24, 10, 3, dtype)
    got = np.asarray(model.ehyb_spmv(*args))
    want = np.asarray(ref.ehyb_spmv_ref(*args))
    np.testing.assert_allclose(got, want, **tol(dtype))


def test_matches_dense_reconstruction():
    """End-to-end ground truth: rebuild A densely, compare A @ x."""
    rng = np.random.default_rng(5)
    p, w, r, e, we = 2, 3, 8, 6, 2
    xp, cols, vals, er_cols, er_vals, er_yidx = make_instance(rng, p, w, r, e, we, np.float64)
    n = p * r
    a = ref.dense_from_ehyb(n, cols, vals, er_cols, er_vals, er_yidx)
    want = np.asarray(a) @ np.asarray(xp)
    got = np.asarray(model.ehyb_spmv(xp, cols, vals, er_cols, er_vals, er_yidx))
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-12)


@settings(max_examples=25, deadline=None)
@given(
    p=st.integers(1, 3),
    w=st.integers(1, 5),
    r8=st.integers(1, 4),
    e=st.integers(1, 16),
    we=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_full_spmv_hypothesis(p, w, r8, e, we, seed):
    rng = np.random.default_rng(seed)
    args = make_instance(rng, p, w, 8 * r8, e, we, np.float64)
    got = np.asarray(model.ehyb_spmv(*args))
    want = np.asarray(ref.ehyb_spmv_ref(*args))
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


def test_er_scatter_accumulates_duplicates():
    """Two ER rows targeting the same output row must both land."""
    p, w, r = 1, 1, 8
    cols = jnp.zeros((p, w, r), jnp.int32)
    vals = jnp.zeros((p, w, r), jnp.float64)
    er_cols = jnp.array([[1], [1]], jnp.int32)
    er_vals = jnp.array([[2.0], [3.0]], jnp.float64)
    er_yidx = jnp.array([4, 4], jnp.int32)
    xp = jnp.arange(8, dtype=jnp.float64)
    y = np.asarray(model.ehyb_spmv(xp, cols, vals, er_cols, er_vals, er_yidx))
    assert y[4] == pytest.approx(5.0 * xp[1])


def _spd_tridiag_instance(n_parts, r):
    """SPD tridiagonal system laid out as EHYB (all in-partition except
    the couplings that cross partition boundaries, which go to ER)."""
    n = n_parts * r
    w = 3
    cols = np.zeros((n_parts, w, r), np.int32)
    vals = np.zeros((n_parts, w, r), np.float64)
    er = []
    for i in range(n):
        pi, ri = divmod(i, r)
        slot = 0
        for j, v in ((i, 2.5), (i - 1, -1.0), (i + 1, -1.0)):
            if j < 0 or j >= n:
                continue
            if j // r == pi:
                cols[pi, slot, ri] = j % r
                vals[pi, slot, ri] = v
                slot += 1
            else:
                er.append((i, j, v))
    e = max(len(er), 1)
    er_cols = np.zeros((e, 1), np.int32)
    er_vals = np.zeros((e, 1), np.float64)
    er_yidx = np.zeros((e,), np.int32)
    for k, (i, j, v) in enumerate(er):
        er_cols[k, 0] = j
        er_vals[k, 0] = v
        er_yidx[k] = i
    return tuple(
        jnp.asarray(a) for a in (cols, vals, er_cols, er_vals, er_yidx)
    )


def test_cg_step_converges_on_spd():
    cols, vals, er_cols, er_vals, er_yidx = _spd_tridiag_instance(2, 16)
    n = 32
    rng = np.random.default_rng(9)
    b = jnp.asarray(rng.standard_normal(n))
    diag_inv = jnp.full((n,), 1.0 / 2.5)
    x = jnp.zeros(n)
    r_ = b
    z = diag_inv * r_
    p_ = z
    rz = jnp.dot(r_, z)
    r0 = float(jnp.linalg.norm(r_))
    for _ in range(60):
        x, r_, p_, rz, _ = model.cg_step(
            x, r_, p_, rz, cols, vals, er_cols, er_vals, er_yidx, diag_inv
        )
    rk = float(jnp.linalg.norm(r_))
    assert rk < 1e-8 * r0, f"CG did not converge: {rk} vs {r0}"
    # Check the solution truly solves the system.
    ax = model.ehyb_spmv(x, cols, vals, er_cols, er_vals, er_yidx)
    np.testing.assert_allclose(np.asarray(ax), np.asarray(b), rtol=1e-6, atol=1e-8)
