"""Pytest config: make the `compile` package importable whether pytest
runs from `python/` or the repo root, and enable x64 before any other
jax use."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import jax

jax.config.update("jax_enable_x64", True)
